//! The iBSP execution engine (paper §IV-B "Orchestration and Concurrency").
//!
//! One [`GopherEngine`] drives an [`Application`] over a deployed
//! collection: the outer loop iterates **timesteps** (graph instances) in
//! the order dictated by the pattern — strictly sequential for
//! [`Pattern::Sequential`], a parallel pool for `Independent` /
//! `EventuallyDependent` — and each timestep runs an inner **BSP** over
//! all subgraphs of all hosts:
//!
//! ```text
//! timestep t:                        (instance data loaded at BSP start)
//!   superstep 1..k:
//!     par-for each active subgraph:  compute(ctx, sgi, msgs)
//!     barrier; route messages (local free, remote charged to the net model)
//!   until all halted && no messages in flight
//! ```
//!
//! Messages to the next timestep are buffered by the driver and delivered
//! at superstep 1 of timestep t+1; merge messages accumulate across all
//! timesteps and feed `Application::merge` at the end.

use crate::cluster::{ClusterSpec, NetworkClock};
use crate::gofs::{Projection, Store, SubgraphInstance};
use crate::graph::{SubgraphId, Timestep};
use crate::gopher::{Application, ComputeCtx, Outbox, Pattern, Payload, SubgraphProgram};
use crate::metrics::{keys, Metrics};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Per-run options.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Restrict to these timesteps (default: all instances, in order).
    pub timesteps: Option<Vec<Timestep>>,
    /// Or restrict by time range (GoFS metadata filter, §V-B).
    pub time_range: Option<(i64, i64)>,
    /// Safety bound on supersteps per timestep.
    pub max_supersteps: usize,
    /// Worker threads for BSP compute.
    pub workers: usize,
    /// Concurrent timesteps for the independent/eventually-dependent
    /// patterns ("temporal concurrency", §IV-B).
    pub temporal_workers: usize,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            timesteps: None,
            time_range: None,
            max_supersteps: 10_000,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            temporal_workers: 4,
        }
    }
}

/// Per-timestep observables (Fig. 7 bars are `wall_s` + `sim_*`).
#[derive(Debug, Clone, Default)]
pub struct TimestepStats {
    pub timestep: Timestep,
    pub supersteps: usize,
    pub wall_s: f64,
    pub slices_read: u64,
    pub slice_bytes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub msgs_local: u64,
    pub msgs_remote: u64,
    pub msg_bytes_remote: u64,
    pub sim_net_ns: u64,
    pub sim_disk_ns: u64,
}

/// Whole-run result.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub per_timestep: Vec<TimestepStats>,
    pub merge_wall_s: f64,
    pub total_wall_s: f64,
}

impl RunStats {
    pub fn total_supersteps(&self) -> usize {
        self.per_timestep.iter().map(|t| t.supersteps).sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.per_timestep.iter().map(|t| t.msgs_local + t.msgs_remote).sum()
    }
}

/// The distributed Gopher runtime over one deployed collection.
pub struct GopherEngine {
    stores: Vec<Arc<Store>>,
    spec: ClusterSpec,
    metrics: Arc<Metrics>,
    /// sgid -> (host, subgraph local index)
    directory: HashMap<SubgraphId, (usize, usize)>,
}

impl GopherEngine {
    pub fn new(stores: Vec<Store>, spec: ClusterSpec, metrics: Arc<Metrics>) -> Self {
        let stores: Vec<Arc<Store>> = stores.into_iter().map(Arc::new).collect();
        let mut directory = HashMap::new();
        for (h, s) in stores.iter().enumerate() {
            for sg in &s.shared().subgraphs {
                directory.insert(sg.id, (h, sg.id.local()));
            }
        }
        GopherEngine { stores, spec, metrics, directory }
    }

    pub fn stores(&self) -> &[Arc<Store>] {
        &self.stores
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn n_instances(&self) -> usize {
        self.stores[0].n_instances()
    }

    /// Total subgraphs across all hosts.
    pub fn n_subgraphs(&self) -> usize {
        self.directory.len()
    }

    /// Run `app` to completion. Returns per-timestep stats.
    pub fn run(&self, app: &dyn Application, opts: &RunOptions) -> Result<RunStats> {
        let t0 = Instant::now();
        let timesteps: Vec<Timestep> = match (&opts.timesteps, &opts.time_range) {
            (Some(ts), _) => ts.clone(),
            (None, Some((lo, hi))) => self.stores[0].filter_time(*lo, *hi),
            (None, None) => (0..self.n_instances()).collect(),
        };
        if timesteps.is_empty() {
            bail!("no timesteps selected");
        }
        let proj = app.projection(self.stores[0].vertex_schema(), self.stores[0].edge_schema());

        let mut stats = RunStats::default();
        let merge_msgs: Mutex<Vec<Payload>> = Mutex::new(Vec::new());

        match app.pattern() {
            Pattern::Sequential => {
                // One BSP at a time; cross-timestep mailbox threads through.
                let mut carry: HashMap<SubgraphId, Vec<Payload>> = HashMap::new();
                for (i, &t) in timesteps.iter().enumerate() {
                    let first = i == 0;
                    let (ts_stats, next) = self.run_timestep(
                        app,
                        &proj,
                        t,
                        timesteps.len(),
                        std::mem::take(&mut carry),
                        first,
                        opts.workers,
                        opts.max_supersteps,
                        &merge_msgs,
                    )?;
                    carry = next;
                    stats.per_timestep.push(ts_stats);
                    self.metrics.incr(keys::TIMESTEPS);
                }
            }
            Pattern::Independent | Pattern::EventuallyDependent => {
                // Temporal concurrency: a pool of timestep workers, each
                // running a whole BSP (spatial workers divided among them).
                let tw = opts.temporal_workers.max(1).min(timesteps.len());
                let inner_workers = (opts.workers / tw).max(1);
                let next_idx = AtomicUsize::new(0);
                let results: Mutex<Vec<TimestepStats>> = Mutex::new(Vec::new());
                let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
                std::thread::scope(|scope| {
                    for _ in 0..tw {
                        scope.spawn(|| loop {
                            let i = next_idx.fetch_add(1, Ordering::Relaxed);
                            if i >= timesteps.len() || err.lock().unwrap().is_some() {
                                break;
                            }
                            let t = timesteps[i];
                            match self.run_timestep(
                                app,
                                &proj,
                                t,
                                timesteps.len(),
                                HashMap::new(),
                                true, // every instance gets app inputs
                                inner_workers,
                                opts.max_supersteps,
                                &merge_msgs,
                            ) {
                                Ok((ts_stats, next)) => {
                                    debug_assert!(next.is_empty());
                                    results.lock().unwrap().push(ts_stats);
                                    self.metrics.incr(keys::TIMESTEPS);
                                }
                                Err(e) => {
                                    *err.lock().unwrap() = Some(e);
                                }
                            }
                        });
                    }
                });
                if let Some(e) = err.into_inner().unwrap() {
                    return Err(e);
                }
                let mut per = results.into_inner().unwrap();
                per.sort_by_key(|s| s.timestep);
                stats.per_timestep = per;
            }
        }

        // Merge step (eventually-dependent pattern).
        if app.pattern() == Pattern::EventuallyDependent {
            let tm = Instant::now();
            app.merge(merge_msgs.into_inner().unwrap());
            stats.merge_wall_s = tm.elapsed().as_secs_f64();
        }
        stats.total_wall_s = t0.elapsed().as_secs_f64();
        Ok(stats)
    }

    /// Run one BSP timestep. Returns its stats and the next-timestep
    /// mailbox (sequential pattern).
    #[allow(clippy::too_many_arguments)]
    fn run_timestep(
        &self,
        app: &dyn Application,
        proj: &Projection,
        t: Timestep,
        n_timesteps: usize,
        carry_in: HashMap<SubgraphId, Vec<Payload>>,
        with_inputs: bool,
        workers: usize,
        max_supersteps: usize,
        merge_sink: &Mutex<Vec<Payload>>,
    ) -> Result<(TimestepStats, HashMap<SubgraphId, Vec<Payload>>)> {
        let t_start = Instant::now();
        let m0 = self.metrics.snapshot();
        let net_clock = NetworkClock::default();

        // --- Load instance data + create programs (BSP start; Fig. 3). ---
        struct Item {
            sgid: SubgraphId,
            host: usize,
            program: Box<dyn SubgraphProgram>,
            sgi: SubgraphInstance,
            halted: bool,
            inbox: Vec<Payload>,
            outbox: Outbox,
        }
        // Items in (host-major, bin-major) order — the execution and
        // message-routing order is deterministic.
        let mut items: Vec<Mutex<Item>> = Vec::with_capacity(self.n_subgraphs());
        let mut index_of: HashMap<SubgraphId, usize> = HashMap::new();
        for (h, store) in self.stores.iter().enumerate() {
            for sg in store.subgraphs() {
                let sgi = store.read_instance(sg.id.local(), t, proj)?;
                let program = app.create(&sg);
                let mut inbox = Vec::new();
                if with_inputs {
                    inbox.extend(app.initial_messages(&sg, t));
                }
                if let Some(c) = carry_in.get(&sg.id) {
                    inbox.extend(c.iter().cloned());
                }
                index_of.insert(sg.id, items.len());
                items.push(Mutex::new(Item {
                    sgid: sg.id,
                    host: h,
                    program,
                    sgi,
                    halted: false,
                    inbox,
                    outbox: Outbox::default(),
                }));
            }
        }

        let pattern = app.pattern();
        let mut supersteps = 0usize;
        let mut carry_out: HashMap<SubgraphId, Vec<Payload>> = HashMap::new();

        for superstep in 1..=max_supersteps {
            supersteps = superstep;
            // --- Compute phase (parallel over subgraphs). ---
            let cursor = AtomicUsize::new(0);
            let workers = workers.max(1).min(items.len().max(1));
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        let mut item = items[i].lock().unwrap();
                        let active = !item.halted || !item.inbox.is_empty();
                        if !active {
                            continue;
                        }
                        let msgs = std::mem::take(&mut item.inbox);
                        item.halted = false;
                        let Item { sgid, program, sgi, halted, outbox, .. } = &mut *item;
                        let mut ctx = ComputeCtx {
                            sgid: *sgid,
                            timestep: t,
                            superstep,
                            n_timesteps,
                            pattern,
                            outbox,
                            halted,
                        };
                        program.compute(&mut ctx, sgi, &msgs);
                    });
                }
            });
            self.metrics.incr(keys::SUPERSTEPS);

            // --- Barrier: route messages in bulk (deterministic order). ---
            let mut any_inflight = false;
            let mut all_halted = true;
            // (src host, dst host) -> (n msgs, bytes) for the net model.
            let mut batches: HashMap<(usize, usize), (u64, u64)> = HashMap::new();
            let mut merge_local: Vec<Payload> = Vec::new();
            for i in 0..items.len() {
                let mut item = items[i].lock().unwrap();
                let host = item.host;
                let halted = item.halted;
                let outbox = std::mem::take(&mut item.outbox);
                drop(item);
                if !halted {
                    all_halted = false;
                }
                for (to, payload) in outbox.superstep {
                    let &target = index_of
                        .get(&to)
                        .ok_or_else(|| anyhow::anyhow!("message to unknown subgraph {to}"))?;
                    let dst_host = to.partition();
                    if dst_host == host {
                        self.metrics.incr(keys::MSGS_LOCAL);
                    } else {
                        self.metrics.incr(keys::MSGS_REMOTE);
                        self.metrics.add(keys::MSG_BYTES_REMOTE, payload.len() as u64);
                        let b = batches.entry((host, dst_host)).or_insert((0, 0));
                        b.0 += 1;
                        b.1 += payload.len() as u64;
                    }
                    items[target].lock().unwrap().inbox.push(payload);
                    any_inflight = true;
                }
                for (to, payload) in outbox.next_timestep {
                    carry_out.entry(to).or_default().push(payload);
                }
                if !outbox.merge.is_empty() {
                    merge_local.extend(outbox.merge);
                }
            }
            if !merge_local.is_empty() {
                merge_sink.lock().unwrap().extend(merge_local);
            }
            let pairs: Vec<(u64, u64)> = batches.values().copied().collect();
            let net_ns = net_clock.charge_superstep(&self.spec.net, &pairs);
            self.metrics.add(keys::SIM_NET_NS, net_ns);

            if all_halted && !any_inflight {
                break;
            }
            if superstep == max_supersteps {
                bail!("BSP did not converge within {max_supersteps} supersteps");
            }
        }

        let d = self.metrics.snapshot().since(&m0);
        let stats = TimestepStats {
            timestep: t,
            supersteps,
            wall_s: t_start.elapsed().as_secs_f64(),
            slices_read: d.get(keys::SLICES_READ),
            slice_bytes: d.get(keys::SLICE_BYTES),
            cache_hits: d.get(keys::CACHE_HITS),
            cache_misses: d.get(keys::CACHE_MISSES),
            msgs_local: d.get(keys::MSGS_LOCAL),
            msgs_remote: d.get(keys::MSGS_REMOTE),
            msg_bytes_remote: d.get(keys::MSG_BYTES_REMOTE),
            sim_net_ns: net_clock.total_ns(),
            sim_disk_ns: d.get(keys::SIM_DISK_NS),
        };
        Ok((stats, carry_out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{TraceRouteGenerator, TraceRouteParams};
    use crate::gofs::{deploy, DeployConfig, DiskModel, StoreOptions};
    use crate::graph::Schema;
    use crate::partition::Subgraph;
    use std::path::PathBuf;

    fn engine(tag: &str) -> (GopherEngine, PathBuf) {
        let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
        let dir = std::env::temp_dir().join(format!("gopher-engine-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        deploy(&gen, &DeployConfig::new(2, 3, 4), &dir).unwrap();
        let metrics = Arc::new(Metrics::new());
        let opts = StoreOptions {
            cache_slots: 16,
            disk: DiskModel::instant(),
            metrics: metrics.clone(),
        };
        let stores = crate::gofs::open_collection(&dir, &opts).unwrap();
        (GopherEngine::new(stores, ClusterSpec::new(2), metrics), dir)
    }

    /// Counts invocations and passes one token around all subgraphs.
    struct CountApp {
        pattern: Pattern,
        invocations: Arc<Mutex<Vec<(Timestep, usize)>>>,
    }

    struct CountProgram {
        invocations: Arc<Mutex<Vec<(Timestep, usize)>>>,
    }

    impl SubgraphProgram for CountProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &crate::gofs::SubgraphInstance, _msgs: &[Payload]) {
            self.invocations.lock().unwrap().push((ctx.timestep, ctx.superstep));
            ctx.vote_to_halt();
        }
    }

    impl Application for CountApp {
        fn name(&self) -> &str {
            "count"
        }
        fn pattern(&self) -> Pattern {
            self.pattern
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(CountProgram { invocations: self.invocations.clone() })
        }
    }

    #[test]
    fn every_subgraph_runs_once_per_timestep() {
        let (eng, dir) = engine("count-seq");
        let inv = Arc::new(Mutex::new(Vec::new()));
        let app = CountApp { pattern: Pattern::Sequential, invocations: inv.clone() };
        let stats = eng.run(&app, &RunOptions::default()).unwrap();
        assert_eq!(stats.per_timestep.len(), 12);
        let n_sg = eng.n_subgraphs();
        assert_eq!(inv.lock().unwrap().len(), 12 * n_sg);
        assert!(stats.per_timestep.iter().all(|s| s.supersteps == 1));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn independent_pattern_covers_all_timesteps() {
        let (eng, dir) = engine("count-ind");
        let inv = Arc::new(Mutex::new(Vec::new()));
        let app = CountApp { pattern: Pattern::Independent, invocations: inv.clone() };
        let stats = eng.run(&app, &RunOptions { temporal_workers: 3, ..Default::default() }).unwrap();
        assert_eq!(stats.per_timestep.len(), 12);
        // sorted by timestep regardless of completion order
        let ts: Vec<usize> = stats.per_timestep.iter().map(|s| s.timestep).collect();
        assert_eq!(ts, (0..12).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Ping app: subgraph 0 sends a token to every other subgraph; they
    /// reply; checks message routing + reactivation.
    struct PingApp;

    struct PingProgram;

    impl SubgraphProgram for PingProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, sgi: &crate::gofs::SubgraphInstance, msgs: &[Payload]) {
            let me = ctx.sgid;
            if ctx.superstep == 1 && me == SubgraphId::new(0, 0) {
                // discover peers via remote edges and also self-partition
                for r in &sgi.sg.remote {
                    ctx.send_to_subgraph(r.dst_subgraph, b"ping".to_vec());
                }
            } else {
                for m in msgs {
                    if m.as_slice() == b"ping" {
                        ctx.send_to_subgraph(SubgraphId::new(0, 0), b"pong".to_vec());
                    }
                }
            }
            ctx.vote_to_halt();
        }
    }

    impl Application for PingApp {
        fn name(&self) -> &str {
            "ping"
        }
        fn pattern(&self) -> Pattern {
            Pattern::Sequential
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(PingProgram)
        }
    }

    #[test]
    fn messages_route_and_reactivate() {
        let (eng, dir) = engine("ping");
        let stats = eng
            .run(&PingApp, &RunOptions { timesteps: Some(vec![0]), ..Default::default() })
            .unwrap();
        let ts = &stats.per_timestep[0];
        // ping + pong rounds => at least 3 supersteps if sg0 has remotes
        if ts.msgs_local + ts.msgs_remote > 0 {
            assert!(ts.supersteps >= 2);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Carry app: each subgraph forwards a counter to the next timestep.
    struct CarryApp {
        seen: Arc<Mutex<Vec<(Timestep, u64)>>>,
    }

    struct CarryProgram {
        seen: Arc<Mutex<Vec<(Timestep, u64)>>>,
    }

    impl SubgraphProgram for CarryProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &crate::gofs::SubgraphInstance, msgs: &[Payload]) {
            let prev = msgs
                .iter()
                .filter_map(|m| m.as_slice().try_into().ok().map(u64::from_le_bytes))
                .max()
                .unwrap_or(0);
            self.seen.lock().unwrap().push((ctx.timestep, prev));
            if ctx.timestep + 1 < ctx.n_timesteps {
                ctx.send_to_next_timestep((prev + 1).to_le_bytes().to_vec());
            }
            ctx.vote_to_halt();
        }
    }

    impl Application for CarryApp {
        fn name(&self) -> &str {
            "carry"
        }
        fn pattern(&self) -> Pattern {
            Pattern::Sequential
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(CarryProgram { seen: self.seen.clone() })
        }
    }

    #[test]
    fn state_flows_across_timesteps() {
        let (eng, dir) = engine("carry");
        let seen = Arc::new(Mutex::new(Vec::new()));
        let app = CarryApp { seen: seen.clone() };
        eng.run(&app, &RunOptions::default()).unwrap();
        let seen = seen.lock().unwrap();
        // At timestep t every subgraph must have received counter == t.
        for &(t, v) in seen.iter() {
            assert_eq!(v as usize, t, "timestep {t} carried {v}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Merge app: each subgraph reports its vertex count; merge sums.
    struct MergeApp {
        total: Arc<Mutex<u64>>,
    }

    struct MergeProgram;

    impl SubgraphProgram for MergeProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, sgi: &crate::gofs::SubgraphInstance, _msgs: &[Payload]) {
            ctx.send_to_merge((sgi.sg.n_vertices() as u64).to_le_bytes().to_vec());
            ctx.vote_to_halt();
        }
    }

    impl Application for MergeApp {
        fn name(&self) -> &str {
            "merge"
        }
        fn pattern(&self) -> Pattern {
            Pattern::EventuallyDependent
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(MergeProgram)
        }
        fn merge(&self, msgs: Vec<Payload>) {
            let sum: u64 = msgs
                .iter()
                .map(|m| u64::from_le_bytes(m.as_slice().try_into().unwrap()))
                .sum();
            *self.total.lock().unwrap() = sum;
        }
    }

    #[test]
    fn merge_receives_all_timesteps_contributions() {
        let (eng, dir) = engine("merge");
        let total = Arc::new(Mutex::new(0));
        let app = MergeApp { total: total.clone() };
        eng.run(&app, &RunOptions::default()).unwrap();
        // 12 timesteps x 300 vertices across all subgraphs
        assert_eq!(*total.lock().unwrap(), 12 * 300);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn time_range_limits_timesteps() {
        let (eng, dir) = engine("range");
        let inv = Arc::new(Mutex::new(Vec::new()));
        let app = CountApp { pattern: Pattern::Sequential, invocations: inv.clone() };
        let stats = eng
            .run(
                &app,
                &RunOptions { time_range: Some((0, 4 * 3600)), ..Default::default() },
            )
            .unwrap();
        assert_eq!(stats.per_timestep.len(), 2); // two 2h windows
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
