//! The iBSP execution engine (paper §IV-B "Orchestration and Concurrency").
//!
//! One [`GopherEngine`] drives an [`Application`] over a deployed
//! collection: the outer loop iterates **timesteps** (graph instances) in
//! the order dictated by the pattern — strictly sequential for
//! [`Pattern::Sequential`], a parallel pool for `Independent` /
//! `EventuallyDependent` — and each timestep runs an inner **BSP** over
//! all subgraphs of all hosts:
//!
//! ```text
//! timestep t:                        (instance data loaded at BSP start)
//!   superstep 1..k:
//!     par-for each active subgraph:  compute(ctx, sgi, msgs)
//!     barrier; route messages (local free, remote charged to the net model)
//!   until all halted && no messages in flight
//! ```
//!
//! Messages to the next timestep are buffered by the driver and delivered
//! at superstep 1 of timestep t+1; merge messages accumulate across all
//! timesteps and feed `Application::merge` at the end.
//!
//! ### Pipelined instance loading (paper Fig. 7 bottleneck)
//!
//! The paper's Fig. 7 shows GoFS load time dominating per-timestep Gopher
//! runtime — the motivation for §V-C temporal packing. The engine attacks
//! the same bottleneck at runtime in two ways:
//!
//! 1. **Parallel load**: at each BSP start, `read_instance` runs across
//!    subgraphs on the worker pool instead of serially on the driver
//!    thread. The [`crate::gofs::SliceCache`] runs its loads outside its
//!    lock with per-key in-flight dedup, so concurrent readers of
//!    distinct slices never serialize and shared slices decode once.
//! 2. **Prefetch (depth-k ring, sequential pattern)**: while timestep
//!    `t`'s supersteps run, background loaders read the next up-to-`k`
//!    timesteps' projected slices ([`RunOptions::prefetch_depth`]). The
//!    ring never runs ahead of cache pressure: its effective depth is
//!    capped so the in-flight timesteps' slice footprint (estimated from
//!    the most recent cold load) fits each store's slot count and byte
//!    budget — prefetching past the cache would evict the very slices
//!    the current BSP is using. The BSP then starts on warm data; only
//!    the part of the load that did not fit under the compute window
//!    blocks.
//!
//! [`TimestepStats`] reports the split: `load_wall_s` is the full wall
//! time of the load, `overlap_s` the portion hidden under the previous
//! timestep's compute; `wall_s` only includes the blocking remainder.
//! `RunOptions { prefetch: false, .. }` restores the unpipelined
//! behavior (benches compare both).
//!
//! ### Continuous runs over growing collections (`RunOptions::follow`)
//!
//! With [`RunOptions::follow`] a run does not stop at the collection's
//! current end: when it drains the known timesteps it calls
//! [`GopherEngine::refresh`] — which re-reads each store's metadata and
//! WAL tail (`gofs::ingest`) — and keeps executing timesteps as they
//! become visible on *every* host. Contract: every timestep the
//! minimum-across-hosts instance count ever covered is processed exactly
//! once; already-sealed groups are never re-read for tail growth (their
//! cache keys are immutable); and the run ends after
//! [`RunOptions::follow_idle_polls`] consecutive empty polls spaced
//! [`RunOptions::follow_poll_ms`] apart (0 = poll forever).
//!
//! * **Sequential**: timesteps execute strictly in order, reusing the
//!   prefetch ring; cross-timestep messages flow exactly as in a batch
//!   run. `ctx.n_timesteps` reports `usize::MAX` (the series is
//!   unbounded).
//! * **Independent / EventuallyDependent**: the driver thread feeds the
//!   temporal pool's work queue from `refresh` (`PoolFeed`); loaders
//!   and compute workers block for their claimed timestep to become
//!   visible, so pool runs stay live over a growing collection. The
//!   merge contract extends to the unbounded series through *emission
//!   hooks* fired in timestep order as the contiguous completed prefix
//!   advances: `Application::on_timestep_complete` (per-timestep
//!   emission, independent pattern) and `Application::merge_incremental`
//!   (incremental merge emission, eventually-dependent pattern). The
//!   final `Application::merge` still runs when the follow run ends,
//!   over the full series in timestep order — so a follow run's outputs
//!   are bit-identical to a batch run over the same final collection.
//!
//! Either way the run publishes its lag through the PR 4 flow gate
//! ([`GopherEngine::flow_gate`]) — the sequential loop from its next
//! timestep, the pool from its completed watermark — and closes the gate
//! on every exit path.
//!
//! ### Message routing (overlapped with compute)
//!
//! Routing is two-phase. **Staging** (`stage_outbox`) groups one
//! subgraph's outbox per destination subgraph and pushes the groups —
//! tagged with the source's item index — into per-destination shards;
//! with [`RunOptions::overlap_routing`] (default) each compute worker
//! stages its subgraph the moment that subgraph's `compute` returns, so
//! early finishers' messages route while stragglers still compute (the
//! same overlap idea as the instance prefetcher, one level down). The
//! **barrier** then folds the per-item audits in item order, sorts each
//! destination's chunks by source index, and delivers each group with
//! one bulk `extend`, fanning the delivery loop out over the worker pool
//! when more than one destination has traffic (destinations are
//! disjoint, so the fan-out cannot reorder anything a destination
//! observes).
//!
//! Determinism contract: delivery order per destination is (source item
//! index, send order within that source) — exactly the order a
//! single-threaded in-item-order drain produces — and error precedence,
//! next-timestep carry order, merge order, message counts and network
//! charges are folded in item order, so every observable (stats and app
//! outputs) is bit-identical whether routing overlaps or not.
//! `overlap_routing: false` runs the SAME staging machinery, just
//! entirely at the barrier on one thread — so the on/off comparison
//! isolates the scheduling change (where staging runs), not an
//! implementation difference; the determinism suite and the
//! `perf_hotpath` probe assert output equality.
//!
//! Destination *hosts* are resolved through the engine's directory —
//! `SubgraphId::partition()` encodes the partition id, which is not
//! necessarily the host index a store was opened under — so the network
//! model always charges the true (src host, dst host) pair, and an
//! unknown destination is a clean error.
//!
//! ### Temporal-pool prefetch (Independent / EventuallyDependent)
//!
//! Under temporal concurrency each pool worker used to load its own
//! timestep serially before computing it. With [`RunOptions::prefetch`]
//! (default) a shared prefetch queue decouples the two: dedicated
//! loader threads pull upcoming timesteps into a bounded ready set that
//! compute workers consume in claim order, so one timestep's load
//! overlaps other timesteps' compute across the whole pool. The bound
//! reuses the depth-k ring's cache-pressure cap (`prefetch_cap`) on top
//! of the pool width, so prefetch never thrashes the slice caches.
//! Per-timestep stats report the overlap exactly as the sequential
//! prefetcher does: `overlap_s` is the part of the load hidden under
//! the pool's compute.
//!
//! ### Follow-mode backpressure (`gofs::ingest::FlowGate`)
//!
//! A follow run publishes its lag — decoded bytes of
//! appended-but-not-yet-computed WAL-tail timesteps, summed over hosts —
//! through [`GopherEngine::flow_gate`] after every loop turn, and closes
//! the gate on every exit path. An appender with the gate attached
//! blocks in `append` while the lag exceeds
//! `StoreOptions::tail_high_water_bytes`, closing the unbounded-tail
//! loop.
//!
//! ### The transport seam (in-process vs. real distribution)
//!
//! Everything that crosses hosts funnels through one
//! [`Transport`] call per superstep: the barrier folds its local votes,
//! pre-formatted errors, per-host-pair batch accounting, and (under a
//! distributed transport) the remote-bound message/carry chunks into an
//! [`ExchangeIn`], and applies the
//! [`ExchangeOut`](crate::cluster::transport::ExchangeOut) that comes
//! back —
//! proceed/halt, the globally folded error, the network charge, and
//! inbound chunks. The default [`LocalTransport`] keeps the historical
//! in-process behavior bit-identical (it just charges the
//! `NetworkModel`); `cluster::worker` swaps in a TCP transport and calls
//! [`GopherEngine::run_distributed`], which runs every pattern as a
//! lockstep timestep loop, commits each timestep through
//! [`Transport::commit_timestep`] (durable carry checkpoint + canonical
//! emission), and lets the coordinator fold follow watermarks and the
//! final merge. Chunks are tagged with **global item indices**
//! (host-major, store order within a host), so sorting per destination
//! by source tag reproduces the exact in-process delivery order — that
//! is what makes the two paths' outputs bit-identical
//! (`tests/distributed.rs`).

use crate::cluster::proto::{CarryChunk, MergeChunk, WireChunk};
use crate::cluster::transport::{CommitIn, ExchangeIn, LocalTransport, Transport};
use crate::cluster::ClusterSpec;
use crate::gofs::{FlowGate, Projection, ReadTrace, Store, SubgraphInstance};
use crate::graph::{SubgraphId, Timestep};
use crate::gopher::{Application, ComputeCtx, Outbox, Pattern, Payload, SubgraphProgram};
use crate::metrics::{keys, Metrics};
use crate::partition::Subgraph;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// Per-timestep merge-message buffers: ordered by timestep so the final
/// `Application::merge` (and the incremental emission hooks) see a
/// deterministic message order regardless of pool scheduling.
type MergeMap = Mutex<BTreeMap<Timestep, Vec<Payload>>>;

/// Per-run options.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Restrict to these timesteps (default: all instances, in order).
    pub timesteps: Option<Vec<Timestep>>,
    /// Or restrict by time range (GoFS metadata filter, §V-B).
    pub time_range: Option<(i64, i64)>,
    /// Safety bound on supersteps per timestep.
    pub max_supersteps: usize,
    /// Worker threads for BSP compute and instance loading.
    pub workers: usize,
    /// Concurrent timesteps for the independent/eventually-dependent
    /// patterns ("temporal concurrency", §IV-B).
    pub temporal_workers: usize,
    /// Load upcoming timesteps' instances while others compute: the
    /// sequential pattern's depth-k ring, and the temporal pool's shared
    /// prefetch queue (see the module docs). Results are identical with
    /// or without prefetching — only the wall-clock split changes.
    pub prefetch: bool,
    /// Requested prefetch ring depth `k` (effective depth is additionally
    /// capped by cache pressure; 1 restores the old double buffer).
    pub prefetch_depth: usize,
    /// Stage each subgraph's outbox as soon as its compute finishes
    /// instead of staging every outbox single-threaded at the barrier.
    /// Observables are bit-identical either way (see the module docs);
    /// `false` runs the *same* staging machinery entirely barrier-side,
    /// isolating the scheduling difference for comparison.
    pub overlap_routing: bool,
    /// Keep running past the collection's current end, polling
    /// [`GopherEngine::refresh`] for timesteps a `gofs::ingest` appender
    /// publishes while the run is live. All three patterns: the
    /// sequential loop extends its in-order queue, the temporal pool's
    /// work queue is fed live (see the module docs' follow section).
    pub follow: bool,
    /// Delay between refresh polls when no new timesteps are visible.
    pub follow_poll_ms: u64,
    /// Stop after this many consecutive empty polls (0 = poll forever).
    pub follow_idle_polls: usize,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            timesteps: None,
            time_range: None,
            max_supersteps: 10_000,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            temporal_workers: 4,
            prefetch: true,
            prefetch_depth: 2,
            overlap_routing: true,
            follow: false,
            follow_poll_ms: 25,
            follow_idle_polls: 40,
        }
    }
}

/// Per-timestep observables (Fig. 7 bars are `wall_s` + `sim_*`).
#[derive(Debug, Clone, Default)]
pub struct TimestepStats {
    pub timestep: Timestep,
    pub supersteps: usize,
    /// Wall time on the critical path of this timestep: the *blocking*
    /// part of the instance load plus the BSP supersteps.
    pub wall_s: f64,
    /// Total wall time the instance load took (including any part that
    /// ran concurrently with the previous timestep's compute).
    pub load_wall_s: f64,
    /// Portion of `load_wall_s` hidden under compute by a prefetcher (0
    /// when prefetching is off or for the first timestep): the previous
    /// timestep's compute for the sequential ring, the pool's concurrent
    /// timesteps for the temporal prefetch queue.
    pub overlap_s: f64,
    /// Barrier-side message routing wall time summed over this
    /// timestep's supersteps — the part of routing that could NOT be
    /// hidden under compute.
    pub route_s: f64,
    /// Routing (staging) wall time that ran while another worker was
    /// inside `compute` (a sampled lower bound). 0 when
    /// `overlap_routing` is off, with a single worker, or when staging
    /// only drained after the last compute finished.
    pub route_overlap_s: f64,
    pub slices_read: u64,
    pub slice_bytes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub msgs_local: u64,
    pub msgs_remote: u64,
    pub msg_bytes_remote: u64,
    /// Routed (cross-host) traffic per (src host, dst host) pair, summed
    /// over this timestep's supersteps as (messages, payload bytes) and
    /// sorted by pair — the measurable direction-2 target (edge-locality
    /// work shrinks exactly these numbers).
    pub routed_pairs: Vec<((usize, usize), (u64, u64))>,
    /// Share (%) of owned edges whose destination subgraph lives on
    /// another host — the partitioning-quality denominator for
    /// `routed_pairs`. Constant across a run; cluster-wide in-process,
    /// this host's share under a distributed worker.
    pub edge_cut_pct: f64,
    pub sim_net_ns: u64,
    pub sim_disk_ns: u64,
}

impl TimestepStats {
    /// Load wall time on the critical path (`load_wall_s - overlap_s`).
    pub fn load_blocking_s(&self) -> f64 {
        (self.load_wall_s - self.overlap_s).max(0.0)
    }
}

/// Whole-run result.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub per_timestep: Vec<TimestepStats>,
    pub merge_wall_s: f64,
    pub total_wall_s: f64,
}

impl RunStats {
    pub fn total_supersteps(&self) -> usize {
        self.per_timestep.iter().map(|t| t.supersteps).sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.per_timestep.iter().map(|t| t.msgs_local + t.msgs_remote).sum()
    }

    /// Total blocking load time across timesteps (what prefetch shrinks).
    pub fn total_load_blocking_s(&self) -> f64 {
        self.per_timestep.iter().map(|t| t.load_blocking_s()).sum()
    }

    /// Total cross-host routed payload bytes (sum of every timestep's
    /// `routed_pairs`) — `perf_hotpath` reports this per superstep as
    /// `routed_bytes_per_superstep`.
    pub fn total_routed_bytes(&self) -> u64 {
        self.per_timestep
            .iter()
            .flat_map(|t| t.routed_pairs.iter().map(|&(_, (_, bytes))| bytes))
            .sum()
    }

    /// Whole-run per-host-pair routed traffic: every timestep's
    /// `routed_pairs` folded into one sorted `(src, dst) -> (msgs, bytes)`
    /// list. This is what `run --traffic-out` persists and what the
    /// compaction re-partition pass feeds to `traffic_refine` as migration
    /// weights.
    pub fn routed_pair_totals(&self) -> Vec<((usize, usize), (u64, u64))> {
        let mut acc: std::collections::BTreeMap<(usize, usize), (u64, u64)> =
            std::collections::BTreeMap::new();
        for t in &self.per_timestep {
            for &(pair, (msgs, bytes)) in &t.routed_pairs {
                let e = acc.entry(pair).or_insert((0, 0));
                e.0 += msgs;
                e.1 += bytes;
            }
        }
        acc.into_iter().collect()
    }
}

/// One timestep's instances, loaded ahead of its BSP, plus the GoFS
/// counters attributed to the load. Counters come from per-call
/// [`ReadTrace`]s summed over this timestep's reads, so the attribution
/// is exact even when loads of different timesteps overlap (temporal
/// pools, `temporal_workers > 1`) — the old global-snapshot diff mixed
/// concurrent loads' counts.
struct LoadedTimestep {
    /// (host, subgraph, instance) in (host-major, bin-major) order — the
    /// deterministic execution and routing order.
    items: Vec<(usize, Arc<Subgraph>, SubgraphInstance)>,
    trace: ReadTrace,
    load_wall_s: f64,
}

/// One destination's staging shard: message chunks tagged with their
/// source item index, pushed by whoever stages (compute workers under
/// overlapped routing, the barrier otherwise) and drained sorted by tag.
type RouteShard = Mutex<Vec<(u32, Vec<Payload>)>>;

/// Per-item routing audit produced by [`stage_outbox`]. The barrier
/// folds these in item order, so counts, carry order, merge order and
/// error precedence are identical whether staging ran overlapped (from
/// compute workers) or sequentially (at the barrier).
struct StagedAux {
    halted: bool,
    /// First pattern violation this outbox recorded.
    error: Option<String>,
    /// First destination the directory could not resolve.
    unknown_dest: Option<SubgraphId>,
    any_inflight: bool,
    msgs_local: u64,
    msgs_remote: u64,
    bytes_remote: u64,
    /// (src host, dst host) -> (msgs, bytes) for the network model.
    batches: Vec<((usize, usize), (u64, u64))>,
    /// Messages bound for items on *other processes* (distributed
    /// transports only): (dst global item, msgs in send order), sorted
    /// by destination. Always empty in-process, where every item is in
    /// `index_of`.
    remote: Vec<(u32, Vec<Payload>)>,
    next: Vec<(SubgraphId, Payload)>,
    merge: Vec<Payload>,
}

/// Route one subgraph's outbox: resolve each destination through the
/// directory, group messages per destination preserving send order, and
/// push each group — tagged with the source's item index — into that
/// destination's staging shard. Runs either from a compute worker the
/// moment its subgraph finishes (overlapped routing) or single-threaded
/// at the barrier; the tag makes delivery order independent of which.
fn stage_outbox(
    src_item: usize,
    item_base: u32,
    src_host: usize,
    halted: bool,
    outbox: Outbox,
    index_of: &HashMap<SubgraphId, (usize, usize)>,
    remote: Option<&HashMap<SubgraphId, (usize, u32)>>,
    shards: &[RouteShard],
) -> StagedAux {
    let Outbox { superstep, next_timestep, merge, error } = outbox;
    let mut aux = StagedAux {
        halted,
        error,
        unknown_dest: None,
        any_inflight: false,
        msgs_local: 0,
        msgs_remote: 0,
        bytes_remote: 0,
        batches: Vec::new(),
        remote: Vec::new(),
        next: next_timestep,
        merge,
    };
    let mut batch = |src: usize, dst: usize, bytes: u64| {
        match aux.batches.iter_mut().find(|(p, _)| *p == (src, dst)) {
            Some((_, b)) => {
                b.0 += 1;
                b.1 += bytes;
            }
            None => aux.batches.push(((src, dst), (1, bytes))),
        }
    };
    // Group per destination, preserving this source's send order: O(1)
    // per message via a target-keyed map (a wide fan-out would make a
    // linear destination scan quadratic in the routing hot path). The
    // map's iteration order when pushing chunks below is irrelevant —
    // each (source, target) produces exactly one chunk, and delivery
    // sorts chunks by source. Host-pair batches stay a linear scan
    // (host counts are tiny).
    let mut per_target: HashMap<usize, Vec<Payload>> = HashMap::new();
    let mut per_remote: HashMap<u32, Vec<Payload>> = HashMap::new();
    for (to, payload) in superstep {
        // The destination HOST comes from the engine's view of where the
        // subgraph actually lives, never from `to.partition()` — see the
        // module docs. A destination this process does not hold resolves
        // through the cluster directory under a distributed transport;
        // only a subgraph no host owns is an error.
        match index_of.get(&to) {
            Some(&(target, dst_host)) => {
                if dst_host == src_host {
                    aux.msgs_local += 1;
                } else {
                    aux.msgs_remote += 1;
                    aux.bytes_remote += payload.len() as u64;
                    batch(src_host, dst_host, payload.len() as u64);
                }
                per_target.entry(target).or_default().push(payload);
            }
            None => match remote.and_then(|m| m.get(&to)) {
                Some(&(dst_host, dst_global)) => {
                    aux.msgs_remote += 1;
                    aux.bytes_remote += payload.len() as u64;
                    batch(src_host, dst_host, payload.len() as u64);
                    per_remote.entry(dst_global).or_default().push(payload);
                }
                None => {
                    aux.unknown_dest = Some(to);
                    break; // the barrier fails the run; no point routing on
                }
            },
        }
        aux.any_inflight = true;
    }
    // The chunk tag is the GLOBAL item index (host-major): in-process
    // `item_base` is 0 and this is the plain item index; a distributed
    // worker tags with its cluster-wide offset so receivers sorting by
    // tag reproduce the single-process delivery order.
    for (target, msgs) in per_target {
        shards[target].lock().unwrap().push((item_base + src_item as u32, msgs));
    }
    aux.remote = per_remote.into_iter().collect();
    aux.remote.sort_unstable_by_key(|&(dst, _)| dst);
    aux
}

/// Share (%) of owned edges whose destination subgraph resolves to a
/// different host than the one holding its source, over the given
/// `(host, store)` view. A destination `host_of` cannot place counts as
/// cut (it lives on some other process). 0.0 for an edgeless view.
pub fn compute_edge_cut_pct<'a>(
    stores: impl Iterator<Item = (usize, &'a Store)>,
    host_of: &dyn Fn(SubgraphId) -> Option<usize>,
) -> f64 {
    let (mut cut, mut total) = (0u64, 0u64);
    for (h, s) in stores {
        for sg in &s.shared().subgraphs {
            total += sg.n_edges() as u64;
            for re in &sg.remote {
                if host_of(re.dst_subgraph) != Some(h) {
                    cut += 1;
                }
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        cut as f64 * 100.0 / total as f64
    }
}

/// Shared prefetch queue between the temporal pool's loader threads and
/// its compute workers: loaders `admit` (bounded in-flight), load, then
/// `publish`; compute workers `take` their claim-order timestep. `abort`
/// releases everyone after an error.
struct PoolQueue {
    state: Mutex<PoolState>,
    /// Signaled when a load is published (or the queue aborts).
    ready_cv: Condvar,
    /// Signaled when a loaded timestep is taken (or the queue aborts).
    space_cv: Condvar,
}

struct PoolState {
    /// Completed loads keyed by timestep-queue index, awaiting compute.
    ready: HashMap<usize, Result<LoadedTimestep>>,
    /// Indices claimed by a loader and not yet taken by a computer.
    inflight: usize,
    abort: bool,
}

impl PoolQueue {
    fn new() -> PoolQueue {
        PoolQueue {
            state: Mutex::new(PoolState { ready: HashMap::new(), inflight: 0, abort: false }),
            ready_cv: Condvar::new(),
            space_cv: Condvar::new(),
        }
    }

    /// Claim an in-flight slot, waiting while `cap` are already in
    /// flight. Returns false if the queue aborted instead.
    fn admit(&self, cap: usize) -> bool {
        let mut s = self.state.lock().unwrap();
        while !s.abort && s.inflight >= cap.max(1) {
            s = self.space_cv.wait(s).unwrap();
        }
        if s.abort {
            return false;
        }
        s.inflight += 1;
        true
    }

    /// Give back an admitted slot that will never publish (the loader
    /// found the queue drained).
    fn withdraw(&self) {
        self.state.lock().unwrap().inflight -= 1;
        self.space_cv.notify_all();
    }

    fn publish(&self, i: usize, r: Result<LoadedTimestep>) {
        self.state.lock().unwrap().ready.insert(i, r);
        self.ready_cv.notify_all();
    }

    /// Block until index `i` is loaded and take it; None if aborted.
    fn take(&self, i: usize) -> Option<Result<LoadedTimestep>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.abort {
                return None;
            }
            if let Some(r) = s.ready.remove(&i) {
                s.inflight -= 1;
                drop(s);
                self.space_cv.notify_all();
                return Some(r);
            }
            s = self.ready_cv.wait(s).unwrap();
        }
    }

    fn abort(&self) {
        self.state.lock().unwrap().abort = true;
        self.ready_cv.notify_all();
        self.space_cv.notify_all();
    }
}

/// Follow-mode feed for the temporal pool: the driver (the thread that
/// called `run`) grows `known` as [`GopherEngine::refresh`] makes new
/// timesteps visible on every host; loaders and compute workers block in
/// [`PoolFeed::wait_known`] for the index they claimed. `end` releases
/// everyone — clean end, idle budget exhausted, error, or abort. For a
/// batch (non-follow) run the feed is constructed already ended with the
/// full queue known, which reduces `wait_known` to the old `i >= n_ts`
/// bounds check.
struct PoolFeed {
    /// Queue length visible to workers (monotone; grown under `mx`).
    known: AtomicUsize,
    ended: AtomicBool,
    mx: Mutex<()>,
    cv: Condvar,
}

impl PoolFeed {
    fn new(known: usize, ended: bool) -> PoolFeed {
        PoolFeed {
            known: AtomicUsize::new(known),
            ended: AtomicBool::new(ended),
            mx: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    fn known(&self) -> usize {
        self.known.load(Ordering::Acquire)
    }

    fn ended(&self) -> bool {
        self.ended.load(Ordering::Acquire)
    }

    /// Block until queue index `i` is inside the known queue; false when
    /// the feed ended first (no more timesteps will ever arrive).
    fn wait_known(&self, i: usize) -> bool {
        if i < self.known() {
            return true;
        }
        let mut g = self.mx.lock().unwrap();
        loop {
            if i < self.known() {
                return true;
            }
            if self.ended() {
                return false;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn grow(&self, n: usize) {
        let _g = self.mx.lock().unwrap();
        debug_assert!(n >= self.known());
        self.known.store(n, Ordering::Release);
        self.cv.notify_all();
    }

    fn end(&self) {
        let _g = self.mx.lock().unwrap();
        self.ended.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// Completion watermark over the pool's timestep queue: timesteps finish
/// out of order, but the emission hooks
/// ([`Application::on_timestep_complete`],
/// [`Application::merge_incremental`]) fire in queue order as the
/// contiguous completed prefix advances. The hooks run under this lock —
/// that is what serializes their order across pool workers.
struct Progress {
    state: Mutex<ProgressState>,
}

struct ProgressState {
    done: Vec<bool>,
    /// First queue index not yet complete.
    watermark: usize,
}

impl Progress {
    fn new(n: usize) -> Progress {
        Progress { state: Mutex::new(ProgressState { done: vec![false; n], watermark: 0 }) }
    }

    /// First queue index not yet complete — the pool's follow-mode lag
    /// anchor (everything before it is fully computed).
    fn watermark(&self) -> usize {
        self.state.lock().unwrap().watermark
    }

    /// Mark queue index `i` complete and fire the emission hooks for
    /// every timestep the contiguous completed prefix just gained.
    fn complete(
        &self,
        i: usize,
        app: &dyn Application,
        ts_at: &dyn Fn(usize) -> Timestep,
        merge_map: &MergeMap,
        emit_merge: bool,
    ) {
        let mut s = self.state.lock().unwrap();
        if s.done.len() <= i {
            s.done.resize(i + 1, false);
        }
        s.done[i] = true;
        while s.watermark < s.done.len() && s.done[s.watermark] {
            let t = ts_at(s.watermark);
            app.on_timestep_complete(t);
            if emit_merge {
                let msgs = merge_map.lock().unwrap().get(&t).cloned().unwrap_or_default();
                app.merge_incremental(t, msgs);
            }
            s.watermark += 1;
        }
    }
}

/// Scope guard for pool threads: a loader or computer that panics must
/// abort the queue and end the feed on its way out, or its peers would
/// block forever on a publish/take/wait that never comes (and
/// `thread::scope` would then wait forever instead of propagating the
/// panic).
struct PoolAbortOnPanic<'a> {
    queue: Option<&'a PoolQueue>,
    feed: &'a PoolFeed,
}

impl Drop for PoolAbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            if let Some(q) = self.queue {
                q.abort();
            }
            self.feed.end();
        }
    }
}

/// This process's place in a multi-process cluster, assembled by
/// `cluster::worker` from the coordinator's `Start` message. Present only
/// under [`GopherEngine::run_distributed`]; in-process runs resolve every
/// destination through the engine's own directory.
pub struct DistRun {
    /// This process's host index (== its partition id).
    pub my_host: usize,
    pub n_hosts: usize,
    /// Global item index of this host's first item — the number of items
    /// on lower-numbered hosts. Chunk tags add this offset so the global
    /// (host-major) item order is recoverable everywhere.
    pub item_base: u32,
    /// Every subgraph this process does NOT hold:
    /// sgid -> (owning host, global item index).
    pub remote: HashMap<SubgraphId, (usize, u32)>,
    /// Timesteps visible cluster-wide at start (batch schedule length;
    /// the starting watermark under follow).
    pub n_timesteps: usize,
    /// First timestep to run: 0 on a fresh run, the committed watermark
    /// on rejoin after a crash.
    pub resume_from: Timestep,
    /// Next-timestep carry restored from the durable checkpoint on
    /// rejoin (empty on a fresh run).
    pub resume_carry: HashMap<SubgraphId, Vec<Payload>>,
    /// This host's edge-cut share against the cluster-wide directory.
    pub edge_cut_pct: f64,
}

/// The distributed Gopher runtime over one deployed collection.
pub struct GopherEngine {
    stores: Vec<Arc<Store>>,
    spec: ClusterSpec,
    metrics: Arc<Metrics>,
    /// sgid -> (host, subgraph local index)
    directory: HashMap<SubgraphId, (usize, usize)>,
    /// How supersteps cross the barrier (and, under distribution, hosts):
    /// [`LocalTransport`] by default, swapped by `cluster::worker`.
    transport: Arc<dyn Transport>,
    /// Share (%) of owned edges whose destination subgraph lives on a
    /// different host, per this engine's own directory.
    edge_cut_pct: f64,
    /// Follow-mode backpressure gate, created lazily (see
    /// [`GopherEngine::flow_gate`]).
    flow_gate: OnceLock<Arc<FlowGate>>,
}

impl GopherEngine {
    pub fn new(stores: Vec<Store>, spec: ClusterSpec, metrics: Arc<Metrics>) -> Self {
        let stores: Vec<Arc<Store>> = stores.into_iter().map(Arc::new).collect();
        let mut directory = HashMap::new();
        for (h, s) in stores.iter().enumerate() {
            for sg in &s.shared().subgraphs {
                directory.insert(sg.id, (h, sg.id.local()));
            }
        }
        let edge_cut_pct = compute_edge_cut_pct(
            stores.iter().enumerate().map(|(h, s)| (h, s.as_ref())),
            &|sgid| directory.get(&sgid).map(|&(h, _)| h),
        );
        let transport: Arc<dyn Transport> = Arc::new(LocalTransport::new(spec.net.clone()));
        GopherEngine {
            stores,
            spec,
            metrics,
            directory,
            transport,
            edge_cut_pct,
            flow_gate: OnceLock::new(),
        }
    }

    /// Swap the transport (a `cluster::worker` installs its TCP
    /// transport before calling [`GopherEngine::run_distributed`]).
    pub fn set_transport(&mut self, transport: Arc<dyn Transport>) {
        self.transport = transport;
    }

    /// The cluster shape this engine was built for.
    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Share (%) of owned edges cut by the host placement (per this
    /// engine's own directory — cluster-wide for an in-process engine).
    pub fn edge_cut_pct(&self) -> f64 {
        self.edge_cut_pct
    }

    /// The follow-mode backpressure gate for this engine's collection,
    /// created on first call with the strictest (smallest non-zero)
    /// `StoreOptions::tail_high_water_bytes` across hosts. Attach it to
    /// the `CollectionAppender` feeding the collection
    /// (`CollectionAppender::attach_gate`); a follow run publishes its
    /// lag through it after every loop turn and closes it on exit, so
    /// an attached appender blocks while analytics lags past the mark
    /// and always releases when the run ends.
    pub fn flow_gate(&self) -> Arc<FlowGate> {
        self.flow_gate
            .get_or_init(|| {
                let hwm = self
                    .stores
                    .iter()
                    .map(|s| s.tail_high_water_bytes())
                    .filter(|&b| b > 0)
                    .min()
                    .unwrap_or(0);
                Arc::new(FlowGate::new(hwm))
            })
            .clone()
    }

    pub fn stores(&self) -> &[Arc<Store>] {
        &self.stores
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn n_instances(&self) -> usize {
        self.stores[0].n_instances()
    }

    /// Total subgraphs across all hosts.
    pub fn n_subgraphs(&self) -> usize {
        self.directory.len()
    }

    /// Run `app` to completion. Returns per-timestep stats.
    pub fn run(&self, app: &dyn Application, opts: &RunOptions) -> Result<RunStats> {
        let t0 = Instant::now();
        if opts.follow && (opts.timesteps.is_some() || opts.time_range.is_some()) {
            bail!("RunOptions::follow cannot combine with explicit timesteps or a time range");
        }
        let timesteps: Vec<Timestep> = match (&opts.timesteps, &opts.time_range) {
            (Some(ts), _) => ts.clone(),
            (None, Some((lo, hi))) => self.stores[0].filter_time(*lo, *hi),
            // Schedule only what every host can serve: partitions of a
            // growing collection publish independently, so per-host
            // visible counts can be briefly skewed (mid-append crash, or
            // a run concurrent with a live appender).
            (None, None) => {
                let n = self.stores.iter().map(|s| s.n_instances()).min().unwrap_or(0);
                (0..n).collect()
            }
        };
        if timesteps.is_empty() && !opts.follow {
            bail!("no timesteps selected");
        }
        let proj = app.projection(self.stores[0].vertex_schema(), self.stores[0].edge_schema());

        let mut stats = RunStats::default();
        let merge_msgs: MergeMap = Mutex::new(BTreeMap::new());

        // Whatever happens below — clean end, error, or a panic
        // unwinding out of a compute scope — a follow consumer that
        // stops consuming must release any appender blocked on the flow
        // gate. Drop guard, re-resolved at drop time so an appender that
        // attached mid-run is covered too. (A previous follow run may
        // have closed the gate on its way out; this run is the consumer
        // now.)
        struct FollowGateGuard<'a>(&'a GopherEngine);
        impl Drop for FollowGateGuard<'_> {
            fn drop(&mut self) {
                if let Some(gate) = self.0.flow_gate.get() {
                    gate.close();
                }
            }
        }
        if opts.follow {
            if let Some(gate) = self.flow_gate.get() {
                gate.reopen();
            }
        }
        let _gate_guard = opts.follow.then(|| FollowGateGuard(self));

        match app.pattern() {
            Pattern::Sequential => {
                // One BSP at a time; cross-timestep mailbox threads
                // through. A depth-k ring of scoped loader threads reads
                // upcoming timesteps while the current BSP runs; under
                // follow mode the queue grows as refresh() finds newly
                // published timesteps.
                let mut carry: HashMap<SubgraphId, Vec<Payload>> = HashMap::new();
                let proj_ref = &proj;
                let load_workers = opts.workers;
                let n_ts_known = timesteps.len();
                let result: Result<()> = std::thread::scope(|scope| {
                    let mut queue = timesteps;
                    let mut i = 0usize;
                    let mut idle_polls = 0usize;
                    let mut ring: VecDeque<(
                        Timestep,
                        std::thread::ScopedJoinHandle<'_, Result<LoadedTimestep>>,
                    )> = VecDeque::new();
                    let mut next_spawn = 0usize; // queue index the ring has reached
                    // Per-timestep slice footprint, estimated from the
                    // most recent load that actually hit disk — feeds the
                    // cache-pressure cap on the ring depth.
                    let (mut per_ts_slices, mut per_ts_bytes) = (0u64, 0u64);
                    loop {
                        if opts.follow {
                            // Publish this run's lag (decoded tail bytes
                            // not yet computed) for an appender blocked
                            // on the flow gate. Follow runs reject
                            // explicit timesteps/time ranges at entry,
                            // so the queue is dense from 0 and queue
                            // index == timestep.
                            debug_assert!(
                                i >= queue.len() || queue[i] == i,
                                "follow queue must be dense from 0"
                            );
                            if let Some(gate) = self.flow_gate.get() {
                                let lag: u64 =
                                    self.stores.iter().map(|s| s.tail_bytes_from(i)).sum();
                                gate.publish_lag(lag);
                            }
                        }
                        if i == queue.len() {
                            if !opts.follow {
                                break;
                            }
                            debug_assert!(ring.is_empty(), "ring ahead of the known queue");
                            let visible = self.refresh()?;
                            if visible > queue.len() {
                                queue.extend(queue.len()..visible);
                                idle_polls = 0;
                                continue;
                            }
                            idle_polls += 1;
                            if opts.follow_idle_polls > 0 && idle_polls >= opts.follow_idle_polls
                            {
                                break;
                            }
                            std::thread::sleep(std::time::Duration::from_millis(
                                opts.follow_poll_ms.max(1),
                            ));
                            continue;
                        }
                        let t = queue[i];
                        let (loaded, overlap_s) = match ring.pop_front() {
                            Some((pt, handle)) if pt == t => {
                                let wait0 = Instant::now();
                                let joined: Result<LoadedTimestep> = match handle.join() {
                                    Ok(r) => r,
                                    Err(_) => Err(anyhow!("prefetch loader thread panicked")),
                                };
                                let loaded = joined?;
                                let blocked_s = wait0.elapsed().as_secs_f64();
                                let overlap_s = (loaded.load_wall_s - blocked_s).max(0.0);
                                self.metrics.incr(keys::PREFETCHED_TIMESTEPS);
                                self.metrics
                                    .add(keys::LOAD_OVERLAP_NS, (overlap_s * 1e9) as u64);
                                (loaded, overlap_s)
                            }
                            Some((_, handle)) => {
                                // Defensive: cannot happen while the ring
                                // is fed from this in-order queue.
                                let _ = handle.join();
                                (self.load_timestep(t, proj_ref, load_workers)?, 0.0)
                            }
                            None => (self.load_timestep(t, proj_ref, load_workers)?, 0.0),
                        };
                        self.metrics.add(keys::LOAD_NS, (loaded.load_wall_s * 1e9) as u64);
                        if loaded.trace.slices_read > 0 {
                            per_ts_slices = loaded.trace.cache_misses.max(1);
                            per_ts_bytes = loaded.trace.slice_bytes.max(1);
                        }
                        if opts.prefetch {
                            let depth =
                                self.prefetch_cap(opts.prefetch_depth, per_ts_slices, per_ts_bytes);
                            next_spawn = next_spawn.max(i + 1);
                            while ring.len() < depth && next_spawn < queue.len() {
                                let tn = queue[next_spawn];
                                let engine = self;
                                ring.push_back((
                                    tn,
                                    scope.spawn(move || {
                                        engine.load_timestep(tn, proj_ref, load_workers)
                                    }),
                                ));
                                next_spawn += 1;
                            }
                        }
                        // An open-ended follow run never has a "last"
                        // timestep for apps to special-case.
                        let n_ts_ctx = if opts.follow { usize::MAX } else { n_ts_known };
                        let (ts_stats, next, _) = self.run_timestep(
                            app,
                            t,
                            n_ts_ctx,
                            loaded,
                            overlap_s,
                            std::mem::take(&mut carry),
                            i == 0,
                            opts.workers,
                            opts.max_supersteps,
                            opts.overlap_routing,
                            &merge_msgs,
                            None,
                        )?;
                        carry = next;
                        stats.per_timestep.push(ts_stats);
                        self.metrics.incr(keys::TIMESTEPS);
                        // Sequential runs complete strictly in order, so
                        // the emission watermark is simply "this one".
                        app.on_timestep_complete(t);
                        i += 1;
                    }
                    Ok(())
                });
                result?;
            }
            Pattern::Independent | Pattern::EventuallyDependent => {
                // Temporal concurrency: a pool of timestep workers
                // (spatial workers divided among them), fed — when
                // prefetch is on — by a shared queue of pre-loaded
                // timesteps so loads overlap the pool's compute instead
                // of serializing load-then-compute inside each worker.
                // Under follow mode the driver thread grows the feed
                // from refresh() while loaders and computers block for
                // their claimed index (see the module docs).
                let follow = opts.follow;
                let tw = if follow {
                    opts.temporal_workers.max(1)
                } else {
                    opts.temporal_workers.max(1).min(timesteps.len())
                };
                let inner_workers = (opts.workers / tw).max(1);
                let results: Mutex<Vec<TimestepStats>> = Mutex::new(Vec::new());
                let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
                let n_ts_known = timesteps.len();
                let pattern = app.pattern();
                // A follow queue is dense from 0 (explicit lists are
                // rejected at entry), so queue index == timestep.
                let ts_at = |i: usize| -> Timestep { if follow { i } else { timesteps[i] } };
                let feed = PoolFeed::new(n_ts_known, !follow);
                let progress = Progress::new(n_ts_known);
                let complete_one = |i: usize| {
                    progress.complete(
                        i,
                        app,
                        &ts_at,
                        &merge_msgs,
                        pattern == Pattern::EventuallyDependent,
                    );
                };
                let run_one = |i: usize,
                               loaded: LoadedTimestep,
                               overlap_s: f64|
                 -> Result<TimestepStats> {
                    let t = ts_at(i);
                    self.metrics.add(keys::LOAD_NS, (loaded.load_wall_s * 1e9) as u64);
                    if overlap_s > 0.0 {
                        self.metrics.incr(keys::PREFETCHED_TIMESTEPS);
                        self.metrics.add(keys::LOAD_OVERLAP_NS, (overlap_s * 1e9) as u64);
                    }
                    // An open-ended follow run never has a "last"
                    // timestep for apps to special-case.
                    let n_ts_ctx = if follow { usize::MAX } else { n_ts_known };
                    let (ts_stats, next, _) = self.run_timestep(
                        app,
                        t,
                        n_ts_ctx,
                        loaded,
                        overlap_s,
                        HashMap::new(),
                        true, // every instance gets app inputs
                        inner_workers,
                        opts.max_supersteps,
                        opts.overlap_routing,
                        &merge_msgs,
                        None,
                    )?;
                    // ComputeCtx refuses cross-timestep sends under these
                    // patterns, so this is a should-never-happen backstop
                    // — but a hard one: silently dropping the mailbox
                    // (the old debug_assert!) loses messages in release
                    // builds.
                    if !next.is_empty() {
                        bail!(
                            "internal error: {} next-timestep message(s) buffered \
                             under the {pattern:?} pattern at timestep {t}",
                            next.values().map(Vec::len).sum::<usize>()
                        );
                    }
                    Ok(ts_stats)
                };
                if opts.prefetch {
                    let queue = PoolQueue::new();
                    let next_load = AtomicUsize::new(0);
                    let next_compute = AtomicUsize::new(0);
                    // Footprint estimate from the latest load that hit
                    // disk, feeding the cache-pressure cap.
                    let est_slices = AtomicU64::new(0);
                    let est_bytes = AtomicU64::new(0);
                    let n_loaders = tw.min(opts.prefetch_depth.max(1));
                    std::thread::scope(|scope| {
                        for _ in 0..n_loaders {
                            scope.spawn(|| {
                                // A panicking pool thread must abort the
                                // queue and end the feed, or its peers
                                // (and the scope join) would block
                                // forever.
                                let _guard =
                                    PoolAbortOnPanic { queue: Some(&queue), feed: &feed };
                                loop {
                                    // Admission: never keep more
                                    // timesteps in flight than the pool
                                    // plus what the slice caches can
                                    // absorb.
                                    let cap = tw
                                        + self.prefetch_cap(
                                            opts.prefetch_depth,
                                            est_slices.load(Ordering::Relaxed),
                                            est_bytes.load(Ordering::Relaxed),
                                        );
                                    if !queue.admit(cap) {
                                        return; // aborted
                                    }
                                    let i = next_load.fetch_add(1, Ordering::Relaxed);
                                    if !feed.wait_known(i) {
                                        queue.withdraw();
                                        return; // queue drained for good
                                    }
                                    let r = self.load_timestep(ts_at(i), &proj, inner_workers);
                                    if let Ok(l) = &r {
                                        if l.trace.slices_read > 0 {
                                            est_slices.store(
                                                l.trace.cache_misses.max(1),
                                                Ordering::Relaxed,
                                            );
                                            est_bytes.store(
                                                l.trace.slice_bytes.max(1),
                                                Ordering::Relaxed,
                                            );
                                        }
                                    }
                                    queue.publish(i, r);
                                }
                            });
                        }
                        for _ in 0..tw {
                            scope.spawn(|| {
                                let _guard =
                                    PoolAbortOnPanic { queue: Some(&queue), feed: &feed };
                                loop {
                                    let i = next_compute.fetch_add(1, Ordering::Relaxed);
                                    if !feed.wait_known(i) {
                                        break; // queue drained for good
                                    }
                                    let wait0 = Instant::now();
                                    let Some(loaded) = queue.take(i) else {
                                        break; // aborted
                                    };
                                    let blocked_s = wait0.elapsed().as_secs_f64();
                                    let outcome = loaded.and_then(|l| {
                                        let overlap_s = (l.load_wall_s - blocked_s).max(0.0);
                                        run_one(i, l, overlap_s)
                                    });
                                    match outcome {
                                        Ok(ts_stats) => {
                                            results.lock().unwrap().push(ts_stats);
                                            self.metrics.incr(keys::TIMESTEPS);
                                            complete_one(i);
                                        }
                                        Err(e) => {
                                            *err.lock().unwrap() = Some(e);
                                            queue.abort();
                                            feed.end();
                                            break;
                                        }
                                    }
                                }
                            });
                        }
                        if follow {
                            self.drive_pool_feed(opts, &progress, &feed, Some(&queue), &err);
                        }
                    });
                } else {
                    // Serial load-then-compute per worker (the
                    // pre-prefetch pool; benches compare both).
                    let next_idx = AtomicUsize::new(0);
                    std::thread::scope(|scope| {
                        for _ in 0..tw {
                            scope.spawn(|| {
                                let _guard = PoolAbortOnPanic { queue: None, feed: &feed };
                                loop {
                                    let i = next_idx.fetch_add(1, Ordering::Relaxed);
                                    if !feed.wait_known(i) || err.lock().unwrap().is_some() {
                                        break;
                                    }
                                    let outcome = self
                                        .load_timestep(ts_at(i), &proj, inner_workers)
                                        .and_then(|l| run_one(i, l, 0.0));
                                    match outcome {
                                        Ok(ts_stats) => {
                                            results.lock().unwrap().push(ts_stats);
                                            self.metrics.incr(keys::TIMESTEPS);
                                            complete_one(i);
                                        }
                                        Err(e) => {
                                            *err.lock().unwrap() = Some(e);
                                            feed.end();
                                        }
                                    }
                                }
                            });
                        }
                        if follow {
                            self.drive_pool_feed(opts, &progress, &feed, None, &err);
                        }
                    });
                }
                if let Some(e) = err.into_inner().unwrap() {
                    return Err(e);
                }
                let mut per = results.into_inner().unwrap();
                per.sort_by_key(|s| s.timestep);
                stats.per_timestep = per;
            }
        }

        // Merge step (eventually-dependent pattern): the full series, in
        // timestep order — deterministic however the pool scheduled it.
        if app.pattern() == Pattern::EventuallyDependent {
            let tm = Instant::now();
            app.merge(merge_msgs.into_inner().unwrap().into_values().flatten().collect());
            stats.merge_wall_s = tm.elapsed().as_secs_f64();
        }
        stats.total_wall_s = t0.elapsed().as_secs_f64();
        Ok(stats)
    }

    /// Run `app` as one worker of a multi-process cluster (see
    /// `cluster::worker`, which installs the TCP transport and calls
    /// this). Differences from [`GopherEngine::run`], all invisible in
    /// the outputs:
    ///
    /// * Every pattern runs the lockstep timestep loop — the cluster
    ///   advances one timestep at a time, supersteps synchronized at the
    ///   coordinator's barrier. (Temporal pools would need per-timestep
    ///   barrier multiplexing; out of scope for this transport.)
    /// * Loads are serial (no prefetch ring): the barrier, not the load,
    ///   dominates a socket-coupled run, and the ring's cache-pressure
    ///   feedback would desynchronize lag publishing across hosts.
    /// * Each timestep commits through [`Transport::commit_timestep`]:
    ///   the folded carry is durably checkpointed *before* the commit is
    ///   acknowledged, then `emit(t)` — the app's canonical per-subgraph
    ///   emission — ships to the coordinator, which concatenates hosts
    ///   in host order (= global subgraph order).
    /// * The final merge (eventually-dependent pattern) folds at the
    ///   coordinator from per-item merge chunks ordered (timestep,
    ///   superstep, global item) — the in-process order — and comes back
    ///   on [`Transport::finish_run`]; `merge_incremental` emission is
    ///   not available distributed (the final `merge` contract is).
    /// * Follow mode polls [`Transport::refresh_watermark`] — every host
    ///   offers its local visible count, the coordinator answers the
    ///   cluster min, so all hosts extend (and exhaust their idle-poll
    ///   budgets) in lockstep — and publishes consumer lag through the
    ///   partition's filesystem beacon instead of the in-process gate.
    #[allow(clippy::too_many_arguments)]
    pub fn run_distributed(
        &self,
        app: &dyn Application,
        opts: &RunOptions,
        dist: DistRun,
        emit: &dyn Fn(Timestep) -> String,
    ) -> Result<RunStats> {
        let t0 = Instant::now();
        assert!(
            self.transport.is_distributed(),
            "run_distributed needs a distributed transport (set_transport)"
        );
        assert_eq!(self.stores.len(), 1, "a distributed worker owns exactly one partition");
        if opts.timesteps.is_some() || opts.time_range.is_some() {
            bail!("distributed runs cover the whole collection (no explicit timestep subsets)");
        }
        let mut dist = dist;
        let mut carry = std::mem::take(&mut dist.resume_carry);
        let proj = app.projection(self.stores[0].vertex_schema(), self.stores[0].edge_schema());
        // Distributed merge bypasses this sink (chunks ship in commits);
        // it only exists to satisfy run_timestep's signature.
        let merge_msgs: MergeMap = Mutex::new(BTreeMap::new());
        let mut stats = RunStats::default();
        let pattern = app.pattern();

        // Whatever happens below, a follow consumer that stops consuming
        // must release any producer blocked on its lag beacon — the
        // cross-process analog of the in-process FollowGateGuard.
        struct LagGuard<'a>(&'a dyn Transport);
        impl Drop for LagGuard<'_> {
            fn drop(&mut self) {
                self.0.close_lag();
            }
        }
        let _lag_guard = opts.follow.then(|| LagGuard(&*self.transport));

        let mut known = dist.n_timesteps;
        let mut t = dist.resume_from;
        let mut idle = 0usize;
        loop {
            if opts.follow {
                self.transport.publish_lag(self.stores[0].tail_bytes_from(t));
            }
            if t == known {
                if !opts.follow {
                    break;
                }
                let local = self.refresh()?;
                let visible = self.transport.refresh_watermark(local)?;
                if visible > known {
                    known = visible;
                    idle = 0;
                    continue;
                }
                idle += 1;
                if opts.follow_idle_polls > 0 && idle >= opts.follow_idle_polls {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(opts.follow_poll_ms.max(1)));
                continue;
            }
            let loaded = self.load_timestep(t, &proj, opts.workers)?;
            self.metrics.add(keys::LOAD_NS, (loaded.load_wall_s * 1e9) as u64);
            // Sequential apps seed inputs at the series start only (a
            // rejoin at t > 0 must NOT re-seed); pools seed every
            // timestep — exactly the in-process `with_inputs` choices.
            let with_inputs = match pattern {
                Pattern::Sequential => t == 0,
                Pattern::Independent | Pattern::EventuallyDependent => true,
            };
            let n_ts_ctx = if opts.follow { usize::MAX } else { dist.n_timesteps };
            let (ts_stats, next, merge_chunks) = self.run_timestep(
                app,
                t,
                n_ts_ctx,
                loaded,
                0.0,
                std::mem::take(&mut carry),
                with_inputs,
                opts.workers,
                opts.max_supersteps,
                opts.overlap_routing,
                &merge_msgs,
                Some(&dist),
            )?;
            if pattern != Pattern::Sequential && !next.is_empty() {
                bail!(
                    "internal error: {} next-timestep message(s) buffered \
                     under the {pattern:?} pattern at timestep {t}",
                    next.values().map(Vec::len).sum::<usize>()
                );
            }
            carry = next;
            // Count the timestep *before* committing: the Commit frame
            // piggybacks a metrics snapshot, and the snapshot taken at
            // the barrier must already include the timestep it commits
            // (the coordinator-side parity check is exact).
            self.metrics.incr(keys::TIMESTEPS);
            self.transport.commit_timestep(CommitIn {
                timestep: t,
                output: emit(t),
                merge: merge_chunks,
                carry: &carry,
            })?;
            self.metrics.event("barrier_commit", &[("t", (t as u64).into())]);
            stats.per_timestep.push(ts_stats);
            // The lockstep loop completes strictly in order on every
            // host, so the emission watermark is simply "this one".
            app.on_timestep_complete(t);
            t += 1;
        }

        // End-of-run handshake: every host reports its schedule drained;
        // the coordinator answers with the globally ordered merge
        // payloads for the eventually-dependent final fold.
        if let Some(merge) = self.transport.finish_run()? {
            if pattern == Pattern::EventuallyDependent {
                let tm = Instant::now();
                app.merge(merge);
                stats.merge_wall_s = tm.elapsed().as_secs_f64();
            }
        }
        stats.total_wall_s = t0.elapsed().as_secs_f64();
        Ok(stats)
    }

    /// Follow-mode driver for the temporal pool: runs on the thread that
    /// called [`GopherEngine::run`] while loaders/computers work, growing
    /// the feed as [`GopherEngine::refresh`] makes timesteps visible on
    /// every host, publishing the run's lag through the flow gate from
    /// the completed watermark, and ending the feed after the idle-poll
    /// budget (or on error/abort).
    fn drive_pool_feed(
        &self,
        opts: &RunOptions,
        progress: &Progress,
        feed: &PoolFeed,
        queue: Option<&PoolQueue>,
        err: &Mutex<Option<anyhow::Error>>,
    ) {
        let mut idle = 0usize;
        loop {
            if err.lock().unwrap().is_some() || feed.ended() {
                break;
            }
            // Publish this run's lag (decoded tail bytes at or past the
            // completed watermark) for an appender blocked on the flow
            // gate — the pool analog of the sequential follow loop's
            // per-turn publish. The watermark is the queue index of the
            // first uncomputed timestep, which equals its timestep in a
            // dense follow queue.
            if let Some(gate) = self.flow_gate.get() {
                let wm = progress.watermark();
                let lag: u64 = self.stores.iter().map(|s| s.tail_bytes_from(wm)).sum();
                gate.publish_lag(lag);
            }
            match self.refresh() {
                Ok(visible) => {
                    if visible > feed.known() {
                        feed.grow(visible);
                        idle = 0;
                        continue;
                    }
                }
                Err(e) => {
                    *err.lock().unwrap() = Some(e);
                    if let Some(q) = queue {
                        q.abort();
                    }
                    break;
                }
            }
            idle += 1;
            if opts.follow_idle_polls > 0 && idle >= opts.follow_idle_polls {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(opts.follow_poll_ms.max(1)));
        }
        feed.end();
    }

    /// Refresh every store's view of a growing collection (newly sealed
    /// groups plus each partition's WAL tail — see `gofs::ingest`).
    /// Returns the instance count visible on *every* host; follow mode
    /// only schedules timesteps all hosts can serve, since partitions
    /// publish their seals independently.
    pub fn refresh(&self) -> Result<usize> {
        let mut visible = usize::MAX;
        for s in &self.stores {
            s.refresh()?;
            visible = visible.min(s.n_instances());
        }
        Ok(if visible == usize::MAX { 0 } else { visible })
    }

    /// Cap the prefetch ring depth by cache pressure: never keep more
    /// upcoming timesteps in flight than the per-host slice caches can
    /// hold alongside the executing timestep's working set, by slot count
    /// and (when configured) byte budget. The footprint estimate comes
    /// from the most recent load that touched disk (`cache_misses` ≈
    /// distinct slices per cold timestep); with no estimate — e.g. an
    /// empty projection — there is no cache pressure to respect.
    fn prefetch_cap(&self, requested: usize, per_ts_slices: u64, per_ts_bytes: u64) -> usize {
        let mut cap = requested.max(1);
        if per_ts_slices == 0 {
            return cap;
        }
        let n_stores = self.stores.len().max(1) as u64;
        let slices_per_store = per_ts_slices.div_ceil(n_stores);
        // `trace.slice_bytes` counts *encoded* on-disk bytes while the
        // budget is in decoded resident bytes; apply a ~3x decode
        // expansion allowance, erring toward a shallower ring.
        let bytes_per_store = per_ts_bytes.div_ceil(n_stores).saturating_mul(3);
        for s in &self.stores {
            let slots = s.cache_slots() as u64;
            if slots > 0 {
                let fit = (slots / slices_per_store).saturating_sub(1).max(1);
                cap = cap.min(fit as usize);
            }
            let budget = s.cache_byte_budget();
            if budget > 0 && bytes_per_store > 0 {
                let fit = (budget / bytes_per_store).saturating_sub(1).max(1);
                cap = cap.min(fit as usize);
            }
        }
        cap
    }

    /// Load every subgraph's instance for timestep `t`, fanned out over
    /// `workers` threads (BSP-start parallel load; see module docs).
    /// Items come back in (host-major, bin-major) order regardless of
    /// which worker loaded them.
    fn load_timestep(
        &self,
        t: Timestep,
        proj: &Projection,
        workers: usize,
    ) -> Result<LoadedTimestep> {
        let t0 = Instant::now();
        let work: Vec<(usize, Arc<Subgraph>)> = self
            .stores
            .iter()
            .enumerate()
            .flat_map(|(h, s)| s.subgraphs().into_iter().map(move |sg| (h, sg)))
            .collect();
        let n = work.len();
        let mut slots: Vec<Mutex<Option<Result<(SubgraphInstance, ReadTrace)>>>> =
            Vec::with_capacity(n);
        slots.resize_with(n, || Mutex::new(None));

        let load_one = |h: usize, sg: &Arc<Subgraph>| -> Result<(SubgraphInstance, ReadTrace)> {
            let mut tr = ReadTrace::default();
            let sgi = self.stores[h].read_instance_traced(sg.id.local(), t, proj, &mut tr)?;
            Ok((sgi, tr))
        };
        let workers = workers.max(1).min(n.max(1));
        if workers <= 1 {
            for (i, (h, sg)) in work.iter().enumerate() {
                *slots[i].lock().unwrap() = Some(load_one(*h, sg));
            }
        } else {
            let cursor = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let (h, sg) = &work[i];
                        let r = load_one(*h, sg);
                        *slots[i].lock().unwrap() = Some(r);
                    });
                }
            });
        }

        let mut items = Vec::with_capacity(n);
        let mut trace = ReadTrace::default();
        for (slot, (h, sg)) in slots.into_iter().zip(work) {
            let (sgi, tr) = slot
                .into_inner()
                .unwrap()
                .expect("loader worker left a slot unfilled")?;
            trace.merge(&tr);
            items.push((h, sg, sgi));
        }
        Ok(LoadedTimestep { items, trace, load_wall_s: t0.elapsed().as_secs_f64() })
    }

    /// Run one BSP timestep over pre-loaded instances. Returns its
    /// stats, the next-timestep mailbox (sequential pattern), and — under
    /// a distributed run — this host's merge chunks for the timestep
    /// (always empty in-process, where merges flow into `merge_sink`).
    #[allow(clippy::too_many_arguments)]
    fn run_timestep(
        &self,
        app: &dyn Application,
        t: Timestep,
        n_timesteps: usize,
        loaded: LoadedTimestep,
        overlap_s: f64,
        carry_in: HashMap<SubgraphId, Vec<Payload>>,
        with_inputs: bool,
        workers: usize,
        max_supersteps: usize,
        overlap_routing: bool,
        merge_sink: &MergeMap,
        dist: Option<&DistRun>,
    ) -> Result<(TimestepStats, HashMap<SubgraphId, Vec<Payload>>, Vec<MergeChunk>)> {
        let t_start = Instant::now();
        let LoadedTimestep { items: loaded_items, trace, load_wall_s } = loaded;
        // Chunk tags use global item indices; in-process the base is 0
        // and the tag is the plain item index (see `stage_outbox`).
        let item_base = dist.map_or(0, |d| d.item_base);
        let remote_map = dist.map(|d| &d.remote);

        // --- Create programs over the pre-loaded instances (Fig. 3). ---
        struct Item {
            sgid: SubgraphId,
            host: usize,
            program: Box<dyn SubgraphProgram>,
            sgi: SubgraphInstance,
            halted: bool,
            inbox: Vec<Payload>,
            outbox: Outbox,
        }
        // Items in (host-major, bin-major) order — the execution and
        // message-routing order is deterministic. `index_of` carries the
        // destination host alongside the item index so routing resolves
        // both with one lookup.
        let mut items: Vec<Mutex<Item>> = Vec::with_capacity(loaded_items.len());
        let mut index_of: HashMap<SubgraphId, (usize, usize)> = HashMap::new();
        let mut local_sgids: Vec<SubgraphId> = Vec::with_capacity(loaded_items.len());
        for (h, sg, sgi) in loaded_items {
            // A distributed worker's single store loads as host 0; its
            // items actually live on `my_host`, and the batch accounting
            // must charge the true cluster pair.
            let h = dist.map_or(h, |d| d.my_host);
            let program = app.create(&sg);
            let mut inbox = Vec::new();
            if with_inputs {
                inbox.extend(app.initial_messages(&sg, t));
            }
            if let Some(c) = carry_in.get(&sg.id) {
                inbox.extend(c.iter().cloned());
            }
            index_of.insert(sg.id, (items.len(), h));
            local_sgids.push(sg.id);
            items.push(Mutex::new(Item {
                sgid: sg.id,
                host: h,
                program,
                sgi,
                halted: false,
                inbox,
                outbox: Outbox::default(),
            }));
        }

        let pattern = app.pattern();
        let mut supersteps = 0usize;
        let mut carry_out: HashMap<SubgraphId, Vec<Payload>> = HashMap::new();
        let (mut ts_msgs_local, mut ts_msgs_remote, mut ts_msg_bytes_remote) = (0u64, 0u64, 0u64);
        let (mut ts_route_s, mut ts_route_overlap_s) = (0.0f64, 0.0f64);
        let mut ts_net_ns = 0u64;
        // Per-timestep routed-traffic accounting ((src,dst) host pairs).
        let mut acc_pairs: HashMap<(usize, usize), (u64, u64)> = HashMap::new();
        // Distributed-run buffers: tagged carry (local + inbound remote)
        // folded at timestep end, and per-item merge chunks shipped with
        // the commit. Both stay empty in-process.
        let mut carry_chunks: Vec<CarryChunk> = Vec::new();
        let mut merge_chunks: Vec<MergeChunk> = Vec::new();

        for superstep in 1..=max_supersteps {
            supersteps = superstep;
            // Per-destination staging shards plus one routing audit slot
            // per item (see `stage_outbox` / the module's routing docs).
            let shards: Vec<RouteShard> =
                (0..items.len()).map(|_| Mutex::new(Vec::new())).collect();
            let mut aux_slots: Vec<Mutex<Option<StagedAux>>> =
                (0..items.len()).map(|_| Mutex::new(None)).collect();
            let route_overlap_ns = AtomicU64::new(0);
            // Workers currently inside `program.compute` — the signal
            // that staging time genuinely overlaps compute.
            let active_compute = AtomicUsize::new(0);

            // --- Compute phase (parallel over subgraphs). Under
            // overlapped routing, each worker stages its subgraph's
            // outbox the moment that subgraph's compute returns, so
            // early finishers' messages route while stragglers still
            // compute. ---
            let cursor = AtomicUsize::new(0);
            let workers = workers.max(1).min(items.len().max(1));
            std::thread::scope(|scope| {
                let aux_slots = &aux_slots;
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        let mut item = items[i].lock().unwrap();
                        let active = !item.halted || !item.inbox.is_empty();
                        if active {
                            let msgs = std::mem::take(&mut item.inbox);
                            item.halted = false;
                            let Item { sgid, program, sgi, halted, outbox, .. } = &mut *item;
                            let mut ctx = ComputeCtx {
                                sgid: *sgid,
                                timestep: t,
                                superstep,
                                n_timesteps,
                                pattern,
                                outbox,
                                halted,
                            };
                            active_compute.fetch_add(1, Ordering::Relaxed);
                            program.compute(&mut ctx, sgi, &msgs);
                            active_compute.fetch_sub(1, Ordering::Relaxed);
                        }
                        if overlap_routing {
                            let outbox = std::mem::take(&mut item.outbox);
                            let src_host = item.host;
                            let halted = item.halted;
                            drop(item); // route without holding the item
                            // Staging counts as overlapped only while
                            // some OTHER worker is actually inside
                            // compute (sampled at stage start, so this
                            // is a lower bound): a single worker, or a
                            // pure drain phase after the last compute,
                            // reports zero overlap.
                            let concurrent = active_compute.load(Ordering::Relaxed) > 0;
                            let t0 = Instant::now();
                            let aux = stage_outbox(
                                i, item_base, src_host, halted, outbox, &index_of, remote_map,
                                &shards,
                            );
                            *aux_slots[i].lock().unwrap() = Some(aux);
                            if concurrent {
                                route_overlap_ns.fetch_add(
                                    t0.elapsed().as_nanos() as u64,
                                    Ordering::Relaxed,
                                );
                            }
                        }
                    });
                }
            });
            self.metrics.incr(keys::SUPERSTEPS);
            self.metrics
                .event("superstep", &[("t", (t as u64).into()), ("s", (superstep as u64).into())]);

            // --- Barrier: finish routing. Without overlapped routing,
            // stage every outbox here instead (single-threaded, item
            // order — same machinery, so on/off differ only in WHERE
            // staging runs). Either way, fold the per-item audits in
            // item order and deliver each destination's chunks sorted
            // by source item — delivery order is independent of who
            // staged when. ---
            let barrier0 = Instant::now();
            if !overlap_routing {
                for (i, item) in items.iter_mut().enumerate() {
                    let it = item.get_mut().unwrap();
                    let outbox = std::mem::take(&mut it.outbox);
                    let aux = stage_outbox(
                        i, item_base, it.host, it.halted, outbox, &index_of, remote_map, &shards,
                    );
                    *aux_slots[i].get_mut().unwrap() = Some(aux);
                }
            }
            let mut all_halted = true;
            let mut any_inflight = false;
            let mut merge_local: Vec<Payload> = Vec::new();
            // (src host, dst host) -> (n msgs, bytes) for the net model.
            let mut batches: HashMap<(usize, usize), (u64, u64)> = HashMap::new();
            let mut first_error: Option<String> = None;
            let mut first_unknown: Option<SubgraphId> = None;
            // Remote-bound chunks for this superstep's exchange (empty
            // in-process — every destination is in `index_of`).
            let mut outbound: Vec<WireChunk> = Vec::new();
            let mut outbound_carry: Vec<CarryChunk> = Vec::new();
            for (i, slot) in aux_slots.iter_mut().enumerate() {
                let a = slot.get_mut().unwrap().take().expect("item was never staged");
                if first_error.is_none() {
                    first_error = a.error;
                }
                if first_unknown.is_none() {
                    first_unknown = a.unknown_dest;
                }
                if !a.halted {
                    all_halted = false;
                }
                any_inflight |= a.any_inflight;
                ts_msgs_local += a.msgs_local;
                ts_msgs_remote += a.msgs_remote;
                ts_msg_bytes_remote += a.bytes_remote;
                for (pair, (n, bytes)) in a.batches {
                    let b = batches.entry(pair).or_insert((0, 0));
                    b.0 += n;
                    b.1 += bytes;
                }
                let src_global = item_base + i as u32;
                for (dst, msgs) in a.remote {
                    outbound.push(WireChunk { dst_item: dst, src_item: src_global, msgs });
                }
                match dist {
                    None => {
                        for (to, payload) in a.next {
                            carry_out.entry(to).or_default().push(payload);
                        }
                        merge_local.extend(a.merge);
                    }
                    Some(d) => {
                        // Carry resolves through the cluster directory:
                        // tagged chunks, grouped per destination in send
                        // order. A destination NO host owns parks in an
                        // undeliverable mailbox in-process, so dropping
                        // it here is the same observable.
                        let mut local_g: HashMap<u32, Vec<Payload>> = HashMap::new();
                        let mut remote_g: HashMap<u32, Vec<Payload>> = HashMap::new();
                        for (to, payload) in a.next {
                            if let Some(&(li, _)) = index_of.get(&to) {
                                local_g.entry(item_base + li as u32).or_default().push(payload);
                            } else if let Some(&(_, g)) = d.remote.get(&to) {
                                remote_g.entry(g).or_default().push(payload);
                            }
                        }
                        let ss = superstep as u32;
                        for (dst, msgs) in local_g {
                            carry_chunks.push(CarryChunk {
                                dst_item: dst,
                                superstep: ss,
                                src_item: src_global,
                                msgs,
                            });
                        }
                        for (dst, msgs) in remote_g {
                            outbound_carry.push(CarryChunk {
                                dst_item: dst,
                                superstep: ss,
                                src_item: src_global,
                                msgs,
                            });
                        }
                        if !a.merge.is_empty() {
                            merge_chunks.push(MergeChunk {
                                superstep: ss,
                                src_item: src_global,
                                msgs: a.merge,
                            });
                        }
                    }
                }
            }
            // Deterministic wire order (the per-destination grouping maps
            // iterate arbitrarily): ascending destination within each
            // source, sources already ascending from the fold.
            outbound_carry.sort_by_key(|c| (c.src_item, c.dst_item));

            // The transport folds the barrier decision: error precedence
            // (pattern violations before unknown destinations, item/host
            // order within a kind), the global halt vote, and the network
            // charge — in-process via `LocalTransport` (bit-identical to
            // the historical inline fold), cross-process at the
            // coordinator. Errors bail before any charge.
            let mut pairs: Vec<((usize, usize), (u64, u64))> = batches.into_iter().collect();
            pairs.sort_unstable_by_key(|&(p, _)| p);
            let out = self.transport.exchange(ExchangeIn {
                timestep: t,
                superstep,
                all_halted,
                any_inflight,
                pattern_error: first_error
                    .map(|msg| format!("timestep {t}, superstep {superstep}: {msg}")),
                unknown_dest: first_unknown
                    .map(|to| format!("message to unknown subgraph {to}")),
                pairs: pairs.clone(),
                outbound,
                outbound_carry,
            })?;
            if let Some(err) = out.error {
                bail!("{err}");
            }
            for (pair, (n, bytes)) in pairs {
                let e = acc_pairs.entry(pair).or_insert((0, 0));
                e.0 += n;
                e.1 += bytes;
            }
            // Inbound remote chunks join the staging shards before the
            // drain: their global source tags interleave them with local
            // chunks in exactly the single-process delivery order.
            for c in out.inbound {
                let target = (c.dst_item - item_base) as usize;
                shards[target].lock().unwrap().push((c.src_item, c.msgs));
            }
            carry_chunks.extend(out.inbound_carry);
            // Deliver: per destination, chunks sorted by source item
            // index (unique per chunk), one bulk extend per chunk.
            // Destinations are disjoint, so delivery fans out over the
            // worker pool when more than one destination has traffic;
            // each destination's inbox content is independent of which
            // worker delivers it (and of the fan-out itself), so every
            // observable stays bit-identical to the serial drain —
            // asserted in tests/determinism.rs alongside the staging
            // modes.
            let deliver = |target: usize| {
                let mut chunks = std::mem::take(&mut *shards[target].lock().unwrap());
                if chunks.is_empty() {
                    return;
                }
                chunks.sort_unstable_by_key(|&(src, _)| src);
                let mut item = items[target].lock().unwrap();
                for (_, msgs) in chunks {
                    item.inbox.extend(msgs);
                }
            };
            let busy = shards.iter().filter(|s| !s.lock().unwrap().is_empty()).count();
            if workers > 1 && busy > 1 {
                let cursor = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for _ in 0..workers.min(busy) {
                        scope.spawn(|| loop {
                            let target = cursor.fetch_add(1, Ordering::Relaxed);
                            if target >= shards.len() {
                                break;
                            }
                            deliver(target);
                        });
                    }
                });
            } else {
                for target in 0..shards.len() {
                    deliver(target);
                }
            }
            if !merge_local.is_empty() {
                merge_sink.lock().unwrap().entry(t).or_default().extend(merge_local);
            }
            ts_net_ns += out.net_ns;
            self.metrics.add(keys::SIM_NET_NS, out.net_ns);
            ts_route_s += barrier0.elapsed().as_secs_f64();
            ts_route_overlap_s += route_overlap_ns.load(Ordering::Relaxed) as f64 / 1e9;

            if !out.proceed {
                break;
            }
            if superstep == max_supersteps {
                bail!("BSP did not converge within {max_supersteps} supersteps");
            }
        }

        // Flush this timestep's message counters to the global registry in
        // bulk (exact per-timestep attribution even under temporal
        // concurrency, where the old snapshot-diff approach mixed
        // concurrent timesteps' counts).
        self.metrics.add(keys::MSGS_LOCAL, ts_msgs_local);
        self.metrics.add(keys::MSGS_REMOTE, ts_msgs_remote);
        self.metrics.add(keys::MSG_BYTES_REMOTE, ts_msg_bytes_remote);
        self.metrics.add(keys::ROUTE_NS, (ts_route_s * 1e9) as u64);
        self.metrics.add(keys::ROUTE_OVERLAP_NS, (ts_route_overlap_s * 1e9) as u64);

        // Distributed carry: one stable sort by (destination, superstep,
        // source item) — unique triple — reproduces the in-process fold
        // order (superstep ascending, item ascending, send order within)
        // for every destination, local and inbound chunks interleaved.
        let carry_final = if dist.is_some() {
            carry_chunks.sort_unstable_by_key(|c| (c.dst_item, c.superstep, c.src_item));
            let mut folded: HashMap<SubgraphId, Vec<Payload>> = HashMap::new();
            for c in carry_chunks {
                let sgid = local_sgids[(c.dst_item - item_base) as usize];
                folded.entry(sgid).or_default().extend(c.msgs);
            }
            folded
        } else {
            carry_out
        };

        let mut routed_pairs: Vec<((usize, usize), (u64, u64))> = acc_pairs.into_iter().collect();
        routed_pairs.sort_unstable_by_key(|&(p, _)| p);
        let stats = TimestepStats {
            timestep: t,
            supersteps,
            wall_s: (load_wall_s - overlap_s).max(0.0) + t_start.elapsed().as_secs_f64(),
            load_wall_s,
            overlap_s,
            route_s: ts_route_s,
            route_overlap_s: ts_route_overlap_s,
            slices_read: trace.slices_read,
            slice_bytes: trace.slice_bytes,
            cache_hits: trace.cache_hits,
            cache_misses: trace.cache_misses,
            msgs_local: ts_msgs_local,
            msgs_remote: ts_msgs_remote,
            msg_bytes_remote: ts_msg_bytes_remote,
            routed_pairs,
            edge_cut_pct: dist.map_or(self.edge_cut_pct, |d| d.edge_cut_pct),
            sim_net_ns: ts_net_ns,
            sim_disk_ns: trace.sim_disk_ns,
        };
        Ok((stats, carry_final, merge_chunks))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{TraceRouteGenerator, TraceRouteParams};
    use crate::gofs::{deploy, DeployConfig, DiskModel, StoreOptions};
    use crate::graph::Schema;
    use crate::partition::Subgraph;
    use std::path::PathBuf;

    fn engine(tag: &str) -> (GopherEngine, PathBuf) {
        let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
        let dir = std::env::temp_dir().join(format!("gopher-engine-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        deploy(&gen, &DeployConfig::new(2, 3, 4), &dir).unwrap();
        let metrics = Arc::new(Metrics::new());
        let opts = StoreOptions {
            cache_slots: 16,
            disk: DiskModel::instant(),
            metrics: metrics.clone(),
            ..Default::default()
        };
        let stores = crate::gofs::open_collection(&dir, &opts).unwrap();
        (GopherEngine::new(stores, ClusterSpec::new(2), metrics), dir)
    }

    /// Counts invocations and passes one token around all subgraphs.
    struct CountApp {
        pattern: Pattern,
        invocations: Arc<Mutex<Vec<(Timestep, usize)>>>,
    }

    struct CountProgram {
        invocations: Arc<Mutex<Vec<(Timestep, usize)>>>,
    }

    impl SubgraphProgram for CountProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &crate::gofs::SubgraphInstance, _msgs: &[Payload]) {
            self.invocations.lock().unwrap().push((ctx.timestep, ctx.superstep));
            ctx.vote_to_halt();
        }
    }

    impl Application for CountApp {
        fn name(&self) -> &str {
            "count"
        }
        fn pattern(&self) -> Pattern {
            self.pattern
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(CountProgram { invocations: self.invocations.clone() })
        }
    }

    #[test]
    fn every_subgraph_runs_once_per_timestep() {
        let (eng, dir) = engine("count-seq");
        let inv = Arc::new(Mutex::new(Vec::new()));
        let app = CountApp { pattern: Pattern::Sequential, invocations: inv.clone() };
        let stats = eng.run(&app, &RunOptions::default()).unwrap();
        assert_eq!(stats.per_timestep.len(), 12);
        let n_sg = eng.n_subgraphs();
        assert_eq!(inv.lock().unwrap().len(), 12 * n_sg);
        assert!(stats.per_timestep.iter().all(|s| s.supersteps == 1));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn independent_pattern_covers_all_timesteps() {
        let (eng, dir) = engine("count-ind");
        let inv = Arc::new(Mutex::new(Vec::new()));
        let app = CountApp { pattern: Pattern::Independent, invocations: inv.clone() };
        let stats = eng.run(&app, &RunOptions { temporal_workers: 3, ..Default::default() }).unwrap();
        assert_eq!(stats.per_timestep.len(), 12);
        // sorted by timestep regardless of completion order
        let ts: Vec<usize> = stats.per_timestep.iter().map(|s| s.timestep).collect();
        assert_eq!(ts, (0..12).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// App with a real projection, for load-attribution tests.
    struct ProjApp {
        pattern: Pattern,
    }

    impl Application for ProjApp {
        fn name(&self) -> &str {
            "proj"
        }
        fn pattern(&self) -> Pattern {
            self.pattern
        }
        fn projection(&self, vs: &Schema, es: &Schema) -> Projection {
            Projection::all(vs, es)
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            struct Halt;
            impl SubgraphProgram for Halt {
                fn compute(
                    &mut self,
                    ctx: &mut ComputeCtx<'_>,
                    _sgi: &crate::gofs::SubgraphInstance,
                    _msgs: &[Payload],
                ) {
                    ctx.vote_to_halt();
                }
            }
            Box::new(Halt)
        }
    }

    /// Satellite regression: per-timestep GoFS counters must sum exactly
    /// to the global registry even when timestep loads overlap under the
    /// temporal pool (the old snapshot-diff attribution mixed them).
    #[test]
    fn per_timestep_load_counters_are_exact_under_temporal_concurrency() {
        let (eng, dir) = engine("trace-attr");
        let m0 = eng.metrics().snapshot();
        let stats = eng
            .run(
                &ProjApp { pattern: Pattern::Independent },
                &RunOptions { temporal_workers: 4, ..Default::default() },
            )
            .unwrap();
        let d = eng.metrics().snapshot().since(&m0);
        let per_ts_reads: u64 = stats.per_timestep.iter().map(|s| s.slices_read).sum();
        let per_ts_bytes: u64 = stats.per_timestep.iter().map(|s| s.slice_bytes).sum();
        let per_ts_hits: u64 = stats.per_timestep.iter().map(|s| s.cache_hits).sum();
        let per_ts_misses: u64 = stats.per_timestep.iter().map(|s| s.cache_misses).sum();
        assert_eq!(per_ts_reads, d.get(keys::SLICES_READ));
        assert_eq!(per_ts_bytes, d.get(keys::SLICE_BYTES));
        assert_eq!(per_ts_hits, d.get(keys::CACHE_HITS));
        assert_eq!(per_ts_misses, d.get(keys::CACHE_MISSES));
        assert!(per_ts_reads > 0, "projection should touch slices");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Ping app: subgraph 0 sends a token to every other subgraph; they
    /// reply; checks message routing + reactivation.
    struct PingApp;

    struct PingProgram;

    impl SubgraphProgram for PingProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, sgi: &crate::gofs::SubgraphInstance, msgs: &[Payload]) {
            let me = ctx.sgid;
            if ctx.superstep == 1 && me == SubgraphId::new(0, 0) {
                // discover peers via remote edges and also self-partition
                for r in &sgi.sg.remote {
                    ctx.send_to_subgraph(r.dst_subgraph, b"ping".to_vec());
                }
            } else {
                for m in msgs {
                    if m.as_slice() == b"ping" {
                        ctx.send_to_subgraph(SubgraphId::new(0, 0), b"pong".to_vec());
                    }
                }
            }
            ctx.vote_to_halt();
        }
    }

    impl Application for PingApp {
        fn name(&self) -> &str {
            "ping"
        }
        fn pattern(&self) -> Pattern {
            Pattern::Sequential
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(PingProgram)
        }
    }

    #[test]
    fn messages_route_and_reactivate() {
        let (eng, dir) = engine("ping");
        let stats = eng
            .run(&PingApp, &RunOptions { timesteps: Some(vec![0]), ..Default::default() })
            .unwrap();
        let ts = &stats.per_timestep[0];
        // ping + pong rounds => at least 3 supersteps if sg0 has remotes
        if ts.msgs_local + ts.msgs_remote > 0 {
            assert!(ts.supersteps >= 2);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Carry app: each subgraph forwards a counter to the next timestep.
    struct CarryApp {
        seen: Arc<Mutex<Vec<(Timestep, u64)>>>,
    }

    struct CarryProgram {
        seen: Arc<Mutex<Vec<(Timestep, u64)>>>,
    }

    impl SubgraphProgram for CarryProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &crate::gofs::SubgraphInstance, msgs: &[Payload]) {
            let prev = msgs
                .iter()
                .filter_map(|m| m.as_slice().try_into().ok().map(u64::from_le_bytes))
                .max()
                .unwrap_or(0);
            self.seen.lock().unwrap().push((ctx.timestep, prev));
            if ctx.timestep + 1 < ctx.n_timesteps {
                ctx.send_to_next_timestep((prev + 1).to_le_bytes().to_vec()).unwrap();
            }
            ctx.vote_to_halt();
        }
    }

    impl Application for CarryApp {
        fn name(&self) -> &str {
            "carry"
        }
        fn pattern(&self) -> Pattern {
            Pattern::Sequential
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(CarryProgram { seen: self.seen.clone() })
        }
    }

    fn assert_carry_monotone(eng: &GopherEngine, opts: &RunOptions) {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let app = CarryApp { seen: seen.clone() };
        eng.run(&app, opts).unwrap();
        let seen = seen.lock().unwrap();
        // At timestep t every subgraph must have received counter == t.
        for &(t, v) in seen.iter() {
            assert_eq!(v as usize, t, "timestep {t} carried {v}");
        }
    }

    #[test]
    fn state_flows_across_timesteps() {
        let (eng, dir) = engine("carry");
        assert_carry_monotone(&eng, &RunOptions::default());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Same invariant with the prefetcher disabled: the pipeline must not
    /// change delivery semantics in either mode.
    #[test]
    fn state_flows_across_timesteps_without_prefetch() {
        let (eng, dir) = engine("carry-noprefetch");
        assert_carry_monotone(&eng, &RunOptions { prefetch: false, ..Default::default() });
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Satellite (depth-k ring): a deep prefetch ring must not change
    /// delivery semantics either, including when the requested depth
    /// exceeds the number of remaining timesteps.
    #[test]
    fn state_flows_across_timesteps_with_deep_prefetch_ring() {
        let (eng, dir) = engine("carry-deep");
        assert_carry_monotone(&eng, &RunOptions { prefetch_depth: 5, ..Default::default() });
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The cache-pressure cap never prefetches past what the smallest
    /// cache can hold, and always allows the depth-1 double buffer.
    #[test]
    fn prefetch_cap_respects_cache_pressure() {
        let (eng, dir) = engine("cap"); // stores opened with 16 slots
        // No estimate yet: no pressure to respect.
        assert_eq!(eng.prefetch_cap(4, 0, 0), 4);
        // 16 slots, ~2 slices/timestep/store -> at most 7 ahead.
        assert_eq!(eng.prefetch_cap(64, 4, 0), 7);
        // Footprint larger than the cache: still depth 1.
        assert_eq!(eng.prefetch_cap(8, 1000, 0), 1);
        assert_eq!(eng.prefetch_cap(0, 4, 0), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Follow mode on a static collection behaves like a normal run and
    /// terminates after the idle-poll budget.
    #[test]
    fn follow_mode_processes_everything_then_stops_when_idle() {
        let (eng, dir) = engine("follow-static");
        let inv = Arc::new(Mutex::new(Vec::new()));
        let app = CountApp { pattern: Pattern::Sequential, invocations: inv.clone() };
        let stats = eng
            .run(
                &app,
                &RunOptions {
                    follow: true,
                    follow_poll_ms: 1,
                    follow_idle_polls: 3,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(stats.per_timestep.len(), 12);
        assert_eq!(inv.lock().unwrap().len(), 12 * eng.n_subgraphs());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Follow mode never combines with an explicit schedule: the queue
    /// must stay dense from 0 for the visibility contract to hold.
    #[test]
    fn follow_mode_rejects_explicit_ranges() {
        let (eng, dir) = engine("follow-reject");
        let inv = Arc::new(Mutex::new(Vec::new()));
        for pattern in [Pattern::Sequential, Pattern::Independent] {
            let app = CountApp { pattern, invocations: inv.clone() };
            let err = eng
                .run(
                    &app,
                    &RunOptions { follow: true, timesteps: Some(vec![0]), ..Default::default() },
                )
                .unwrap_err();
            assert!(format!("{err:#}").contains("explicit timesteps"));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Tentpole (pool follow): Independent and EventuallyDependent runs
    /// under follow mode cover a static collection exactly once per
    /// timestep, then stop after the idle budget — with and without the
    /// pool prefetch queue.
    #[test]
    fn follow_mode_pool_processes_everything_then_stops_when_idle() {
        let (eng, dir) = engine("follow-pool-static");
        for pattern in [Pattern::Independent, Pattern::EventuallyDependent] {
            for prefetch in [true, false] {
                let inv = Arc::new(Mutex::new(Vec::new()));
                let app = CountApp { pattern, invocations: inv.clone() };
                let stats = eng
                    .run(
                        &app,
                        &RunOptions {
                            follow: true,
                            follow_poll_ms: 1,
                            follow_idle_polls: 3,
                            temporal_workers: 3,
                            prefetch,
                            ..Default::default()
                        },
                    )
                    .unwrap();
                assert_eq!(stats.per_timestep.len(), 12, "{pattern:?} prefetch={prefetch}");
                let ts: Vec<usize> = stats.per_timestep.iter().map(|s| s.timestep).collect();
                assert_eq!(ts, (0..12).collect::<Vec<_>>());
                assert_eq!(inv.lock().unwrap().len(), 12 * eng.n_subgraphs());
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// App recording the emission-hook protocol: completion order,
    /// per-timestep incremental merge payloads, and the final merge's
    /// message order.
    struct EmitApp {
        completed: Arc<Mutex<Vec<Timestep>>>,
        incremental: Arc<Mutex<Vec<(Timestep, usize)>>>,
        final_msgs: Arc<Mutex<Vec<u64>>>,
    }

    struct EmitProgram;

    impl SubgraphProgram for EmitProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &crate::gofs::SubgraphInstance, _msgs: &[Payload]) {
            ctx.send_to_merge((ctx.timestep as u64).to_le_bytes().to_vec()).unwrap();
            ctx.vote_to_halt();
        }
    }

    impl Application for EmitApp {
        fn name(&self) -> &str {
            "emit"
        }
        fn pattern(&self) -> Pattern {
            Pattern::EventuallyDependent
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(EmitProgram)
        }
        fn on_timestep_complete(&self, t: Timestep) {
            self.completed.lock().unwrap().push(t);
        }
        fn merge_incremental(&self, t: Timestep, msgs: Vec<Payload>) {
            self.incremental.lock().unwrap().push((t, msgs.len()));
        }
        fn merge(&self, msgs: Vec<Payload>) {
            *self.final_msgs.lock().unwrap() = msgs
                .iter()
                .map(|m| u64::from_le_bytes(m.as_slice().try_into().unwrap()))
                .collect();
        }
    }

    /// Tentpole (merge contract over pools): emission hooks fire in
    /// timestep order even though the pool completes timesteps out of
    /// order, each incremental emission carries exactly that timestep's
    /// merge messages, and the final merge sees the full series in
    /// timestep order — deterministically, every run.
    #[test]
    fn emission_hooks_fire_in_timestep_order_with_exact_payloads() {
        let (eng, dir) = engine("emit-order");
        let n_sg = eng.n_subgraphs();
        for opts in [
            RunOptions { temporal_workers: 4, ..Default::default() },
            RunOptions { temporal_workers: 4, prefetch: false, ..Default::default() },
            RunOptions {
                follow: true,
                follow_poll_ms: 1,
                follow_idle_polls: 3,
                temporal_workers: 4,
                ..Default::default()
            },
        ] {
            let app = EmitApp {
                completed: Arc::new(Mutex::new(Vec::new())),
                incremental: Arc::new(Mutex::new(Vec::new())),
                final_msgs: Arc::new(Mutex::new(Vec::new())),
            };
            eng.run(&app, &opts).unwrap();
            assert_eq!(*app.completed.lock().unwrap(), (0..12).collect::<Vec<_>>());
            assert_eq!(
                *app.incremental.lock().unwrap(),
                (0..12).map(|t| (t, n_sg)).collect::<Vec<_>>()
            );
            let want: Vec<u64> =
                (0..12u64).flat_map(|t| std::iter::repeat_n(t, n_sg)).collect();
            assert_eq!(*app.final_msgs.lock().unwrap(), want, "merge order must be by timestep");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Release-build regression for the silent-drop bug: under the
    /// independent pattern `send_to_next_timestep` must (a) return an
    /// error to the caller at send time and (b) fail the whole run — it
    /// must never buffer a message into a mailbox that is then quietly
    /// discarded. This test is assertion-free at the engine layer, so it
    /// proves the behavior in `--release` (where `debug_assert!` — the
    /// old "protection" — compiles out) as well as in debug builds.
    struct RogueSendApp {
        send_results: Arc<Mutex<Vec<bool>>>,
    }

    struct RogueSendProgram {
        send_results: Arc<Mutex<Vec<bool>>>,
    }

    impl SubgraphProgram for RogueSendProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &crate::gofs::SubgraphInstance, _msgs: &[Payload]) {
            let r = ctx.send_to_next_timestep(vec![1, 2, 3]);
            self.send_results.lock().unwrap().push(r.is_err());
            ctx.vote_to_halt();
        }
    }

    impl Application for RogueSendApp {
        fn name(&self) -> &str {
            "rogue-send"
        }
        fn pattern(&self) -> Pattern {
            Pattern::Independent
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(RogueSendProgram { send_results: self.send_results.clone() })
        }
    }

    #[test]
    fn next_timestep_send_under_independent_fails_the_run() {
        let (eng, dir) = engine("rogue");
        let send_results = Arc::new(Mutex::new(Vec::new()));
        let app = RogueSendApp { send_results: send_results.clone() };
        let err = eng
            .run(&app, &RunOptions { timesteps: Some(vec![0, 1]), ..Default::default() })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("Sequential") && msg.contains("Independent"),
            "error should name both patterns: {msg}"
        );
        // Every program that got to send observed a hard Err.
        let results = send_results.lock().unwrap();
        assert!(!results.is_empty());
        assert!(results.iter().all(|&is_err| is_err), "some send silently succeeded");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Merge app: each subgraph reports its vertex count; merge sums.
    struct MergeApp {
        total: Arc<Mutex<u64>>,
    }

    struct MergeProgram;

    impl SubgraphProgram for MergeProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, sgi: &crate::gofs::SubgraphInstance, _msgs: &[Payload]) {
            ctx.send_to_merge((sgi.sg.n_vertices() as u64).to_le_bytes().to_vec()).unwrap();
            ctx.vote_to_halt();
        }
    }

    impl Application for MergeApp {
        fn name(&self) -> &str {
            "merge"
        }
        fn pattern(&self) -> Pattern {
            Pattern::EventuallyDependent
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(MergeProgram)
        }
        fn merge(&self, msgs: Vec<Payload>) {
            let sum: u64 = msgs
                .iter()
                .map(|m| u64::from_le_bytes(m.as_slice().try_into().unwrap()))
                .sum();
            *self.total.lock().unwrap() = sum;
        }
    }

    #[test]
    fn merge_receives_all_timesteps_contributions() {
        let (eng, dir) = engine("merge");
        let total = Arc::new(Mutex::new(0));
        let app = MergeApp { total: total.clone() };
        eng.run(&app, &RunOptions::default()).unwrap();
        // 12 timesteps x 300 vertices across all subgraphs
        assert_eq!(*total.lock().unwrap(), 12 * 300);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn time_range_limits_timesteps() {
        let (eng, dir) = engine("range");
        let inv = Arc::new(Mutex::new(Vec::new()));
        let app = CountApp { pattern: Pattern::Sequential, invocations: inv.clone() };
        let stats = eng
            .run(
                &app,
                &RunOptions { time_range: Some((0, 4 * 3600)), ..Default::default() },
            )
            .unwrap();
        assert_eq!(stats.per_timestep.len(), 2); // two 2h windows
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Tentpole (overlapped routing): message-heavy runs must produce
    /// identical observables with routing staged from compute workers vs
    /// the sequential barrier drain — and the overlapped run must report
    /// zero overlap only when the knob is off.
    #[test]
    fn overlapped_routing_matches_sequential_drain() {
        let (eng, dir) = engine("route-overlap");
        let run = |overlap: bool| {
            let seen = Arc::new(Mutex::new(Vec::new()));
            let app = CarryApp { seen: seen.clone() };
            let stats = eng
                .run(&app, &RunOptions { overlap_routing: overlap, ..Default::default() })
                .unwrap();
            let mut s = seen.lock().unwrap().clone();
            s.sort_unstable();
            let obs: Vec<(usize, usize, u64, u64)> = stats
                .per_timestep
                .iter()
                .map(|ts| (ts.timestep, ts.supersteps, ts.msgs_local, ts.msgs_remote))
                .collect();
            (s, obs)
        };
        let (seen_on, obs_on) = run(true);
        let (seen_off, obs_off) = run(false);
        assert_eq!(seen_on, seen_off, "overlapped routing changed app-visible messages");
        assert_eq!(obs_on, obs_off, "overlapped routing changed per-timestep stats");
        // Ping exercises multi-superstep fan-out both ways too.
        for overlap in [true, false] {
            let stats = eng
                .run(
                    &PingApp,
                    &RunOptions {
                        timesteps: Some(vec![0]),
                        overlap_routing: overlap,
                        ..Default::default()
                    },
                )
                .unwrap();
            let ts = &stats.per_timestep[0];
            assert!(ts.route_s >= 0.0);
            if !overlap {
                assert_eq!(ts.route_overlap_s, 0.0, "no staging overlap when disabled");
            }
            assert!(ts.route_overlap_s >= 0.0);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Tentpole (temporal-pool prefetch): the shared queue must cover
    /// every timestep exactly once, keep per-timestep counters exact,
    /// and report a load/compute overlap split that stays within the
    /// measured load wall time.
    #[test]
    fn temporal_pool_prefetch_covers_all_timesteps_with_exact_counters() {
        let (eng, dir) = engine("pool-prefetch");
        let m0 = eng.metrics().snapshot();
        let stats = eng
            .run(
                &ProjApp { pattern: Pattern::Independent },
                &RunOptions { temporal_workers: 3, prefetch: true, ..Default::default() },
            )
            .unwrap();
        assert_eq!(stats.per_timestep.len(), 12);
        let ts_list: Vec<usize> = stats.per_timestep.iter().map(|s| s.timestep).collect();
        assert_eq!(ts_list, (0..12).collect::<Vec<_>>());
        for ts in &stats.per_timestep {
            assert!(ts.overlap_s >= 0.0);
            assert!(ts.overlap_s <= ts.load_wall_s + 1e-9);
        }
        let d = eng.metrics().snapshot().since(&m0);
        let per_ts_reads: u64 = stats.per_timestep.iter().map(|s| s.slices_read).sum();
        assert_eq!(per_ts_reads, d.get(keys::SLICES_READ), "pool prefetch broke attribution");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The flow gate derives its high-water mark from the stores.
    #[test]
    fn flow_gate_uses_store_high_water_mark() {
        let (eng, dir) = engine("gate-hwm"); // stores opened with hwm 0
        assert_eq!(eng.flow_gate().hwm_bytes(), 0, "no per-store mark -> gate disabled");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The prefetch pipeline accounts load time coherently: overlap never
    /// exceeds the measured load wall time, and every timestep reports a
    /// load split.
    #[test]
    fn load_split_is_reported_and_bounded() {
        let (eng, dir) = engine("load-split");
        let inv = Arc::new(Mutex::new(Vec::new()));
        let app = CountApp { pattern: Pattern::Sequential, invocations: inv };
        let stats = eng.run(&app, &RunOptions::default()).unwrap();
        for ts in &stats.per_timestep {
            assert!(ts.load_wall_s >= 0.0);
            assert!(ts.overlap_s >= 0.0);
            assert!(
                ts.overlap_s <= ts.load_wall_s + 1e-9,
                "overlap {} > load wall {}",
                ts.overlap_s,
                ts.load_wall_s
            );
            assert!(ts.load_blocking_s() >= 0.0);
        }
        // Timestep 0 can never overlap (nothing to hide it under).
        assert_eq!(stats.per_timestep[0].overlap_s, 0.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
