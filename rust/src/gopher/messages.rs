//! Typed message codecs over opaque payloads.
//!
//! Gopher messages are raw bytes (what would cross the wire); apps encode
//! and decode with these helpers, which wrap [`crate::util::wire`] with a
//! fluent API. Keeping serialization explicit lets the network model
//! charge true message sizes — one of the quantities the paper's
//! subgraph-vs-vertex-centric argument is about.

use crate::graph::SubgraphId;
use crate::util::wire::{Dec, Enc};
use anyhow::Result;

/// Builder for a message payload.
#[derive(Default)]
pub struct MsgWriter {
    e: Enc,
}

impl MsgWriter {
    pub fn new() -> Self {
        MsgWriter { e: Enc::new() }
    }

    pub fn tag(mut self, t: u8) -> Self {
        self.e.u8(t);
        self
    }

    pub fn u32(mut self, v: u32) -> Self {
        self.e.varint(v as u64);
        self
    }

    pub fn u64(mut self, v: u64) -> Self {
        self.e.varint(v);
        self
    }

    pub fn f64(mut self, v: f64) -> Self {
        self.e.f64(v);
        self
    }

    pub fn sgid(mut self, id: SubgraphId) -> Self {
        self.e.u64(id.0);
        self
    }

    pub fn str(mut self, s: &str) -> Self {
        self.e.str(s);
        self
    }

    pub fn bytes(mut self, b: &[u8]) -> Self {
        self.e.bytes(b);
        self
    }

    /// Append a (u32, f64) list — the common "vertex updates" shape.
    pub fn pairs_u32_f64(mut self, pairs: &[(u32, f64)]) -> Self {
        self.e.varint(pairs.len() as u64);
        for &(k, v) in pairs {
            self.e.varint(k as u64);
            self.e.f64(v);
        }
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.e.finish()
    }
}

/// Cursor over a received payload.
pub struct MsgReader<'a> {
    d: Dec<'a>,
}

impl<'a> MsgReader<'a> {
    pub fn new(payload: &'a [u8]) -> Self {
        MsgReader { d: Dec::new(payload) }
    }

    pub fn tag(&mut self) -> Result<u8> {
        self.d.u8()
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(self.d.varint()? as u32)
    }

    pub fn u64(&mut self) -> Result<u64> {
        self.d.varint()
    }

    pub fn f64(&mut self) -> Result<f64> {
        self.d.f64()
    }

    pub fn sgid(&mut self) -> Result<SubgraphId> {
        Ok(SubgraphId(self.d.u64()?))
    }

    pub fn str(&mut self) -> Result<&'a str> {
        self.d.str()
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        self.d.bytes()
    }

    pub fn pairs_u32_f64(&mut self) -> Result<Vec<(u32, f64)>> {
        let n = self.d.varint()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let k = self.d.varint()? as u32;
            let v = self.d.f64()?;
            out.push((k, v));
        }
        Ok(out)
    }

    pub fn is_empty(&self) -> bool {
        self.d.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip() {
        let payload = MsgWriter::new()
            .tag(3)
            .u32(42)
            .f64(-1.5)
            .sgid(SubgraphId::new(2, 7))
            .str("plate")
            .pairs_u32_f64(&[(1, 0.5), (9, 2.25)])
            .finish();
        let mut r = MsgReader::new(&payload);
        assert_eq!(r.tag().unwrap(), 3);
        assert_eq!(r.u32().unwrap(), 42);
        assert_eq!(r.f64().unwrap(), -1.5);
        assert_eq!(r.sgid().unwrap(), SubgraphId::new(2, 7));
        assert_eq!(r.str().unwrap(), "plate");
        assert_eq!(r.pairs_u32_f64().unwrap(), vec![(1, 0.5), (9, 2.25)]);
        assert!(r.is_empty());
    }

    #[test]
    fn small_messages_are_compact() {
        // A (vertex, distance) update should be well under 16 bytes.
        let payload = MsgWriter::new().tag(0).u32(1000).f64(3.25).finish();
        assert!(payload.len() <= 12, "payload {} bytes", payload.len());
    }
}
