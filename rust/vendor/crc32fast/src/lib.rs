//! Offline subset of the `crc32fast` crate: table-driven CRC-32 (IEEE
//! 802.3, reflected, polynomial 0xEDB88320) — the same checksum upstream
//! computes, so slice files remain readable if the real crate is swapped
//! back in.

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// One-shot CRC-32 of a byte slice (upstream `crc32fast::hash`).
pub fn hash(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finalize()
}

/// Incremental hasher with the upstream API shape.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Hasher::new()
    }
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard CRC-32/IEEE check values.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(7) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), hash(&data));
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data = vec![0u8; 64];
        let base = hash(&data);
        data[40] ^= 0x10;
        assert_ne!(hash(&data), base);
    }
}
