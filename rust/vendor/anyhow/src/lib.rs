//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! This image has no crates.io access (see DESIGN.md §2.4), so the few
//! ecosystem crates the repo depends on are vendored as minimal
//! implementations under `rust/vendor/`. This one covers the surface the
//! codebase uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/
//! [`ensure!`] macros, and the [`Context`] extension trait for `Result`
//! and `Option`. Error values render their full context chain with the
//! `{:#}` alternate format, exactly like upstream `anyhow`.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as
/// upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus the chain of causes that
/// produced it (outermost first). When built from a typed error
/// ([`Error::new`] or `?` conversion) the original value is retained so
/// callers can recover it with [`Error::downcast_ref`], exactly like
/// upstream — recovery loops branch on typed markers this way.
pub struct Error {
    /// Context chain, outermost message first.
    chain: Vec<String>,
    /// The typed error this chain was built from, if any. Context
    /// wrapping preserves it; message-only errors have none.
    payload: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Construct from a typed error, retaining it for
    /// [`Error::downcast_ref`] (upstream `Error::new`).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(err: E) -> Error {
        Error::from_std(err)
    }

    fn from_std<E: std::error::Error + Send + Sync + 'static>(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain, payload: Some(Box::new(err)) }
    }

    /// Wrap with an outer context message (upstream `Error::context`).
    /// The typed payload survives wrapping, as upstream's cause chain
    /// does.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Borrow the typed error this value was built from, if it is a `T`
    /// (upstream `Error::downcast_ref`).
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_deref().and_then(|p| p.downcast_ref::<T>())
    }

    /// The cause chain, outermost first (upstream returns an iterator of
    /// `dyn Error`; strings carry the same information here).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Root cause message (innermost entry of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole context chain, as upstream does.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any std error. `Error` itself deliberately does NOT
// implement `std::error::Error` (same as upstream), which keeps this
// blanket impl coherent next to the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(err)
    }
}

/// Internal bridge so [`Context`] applies both to std errors and to
/// [`Error`] itself (mirrors upstream's private `ext::StdError`).
pub trait IntoAnyhow: Sized {
    fn into_anyhow(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> IntoAnyhow for E {
    fn into_anyhow(self) -> Error {
        Error::from_std(self)
    }
}

impl IntoAnyhow for Error {
    fn into_anyhow(self) -> Error {
        self
    }
}

/// The `.context(..)` / `.with_context(|| ..)` extension trait.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoAnyhow> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn context_chain_renders_in_alternate_format() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening the store").unwrap_err();
        assert_eq!(format!("{e}"), "opening the store");
        assert_eq!(format!("{e:#}"), "opening the store: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn option_context_and_with_context() {
        let none: Option<u32> = None;
        let e = none.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
        let some = Some(7u32).with_context(|| "unused").unwrap();
        assert_eq!(some, 7);
    }

    #[test]
    fn context_on_anyhow_result_stacks() {
        let r: Result<()> = Err(anyhow!("inner {}", 42));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn downcast_ref_recovers_typed_errors() {
        let e = Error::new(io_err());
        assert_eq!(e.downcast_ref::<std::io::Error>().unwrap().to_string(), "missing file");
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn downcast_ref_survives_context_wrapping() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening the store").unwrap_err().context("outer");
        assert_eq!(format!("{e:#}"), "outer: opening the store: missing file");
        assert!(e.downcast_ref::<std::io::Error>().is_some());
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
    }
}
