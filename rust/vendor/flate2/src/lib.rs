//! Offline subset of the `flate2` API.
//!
//! No crates.io access in this image (DESIGN.md §2.4), so the
//! `DeflateEncoder`/`DeflateDecoder` surface the GoFS slice format uses is
//! backed by a small self-contained byte-oriented LZ codec rather than
//! RFC 1951 DEFLATE. The stream is only ever read back by this same
//! module (slices are written and read by this repo exclusively), the
//! codec is deterministic, and corruption surfaces as `io::Error`s whose
//! messages carry the "deflate" marker the error-handling tests key on.
//!
//! Stream format (after the GoFS slice header):
//! ```text
//! token := 0x00 varint(len) byte[len]          literal run (len >= 1)
//!        | 0x01 varint(len) varint(dist)       copy `len` bytes from
//!                                              `out_len - dist` (overlap
//!                                              allowed, so runs compress)
//! ```

use std::io::{self, Read, Write};

/// Compression level (accepted for API compatibility; the codec has a
/// single greedy mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(pub u32);

impl Compression {
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }

    pub fn fast() -> Compression {
        Compression(1)
    }

    pub fn best() -> Compression {
        Compression(9)
    }

    pub fn none() -> Compression {
        Compression(0)
    }
}

impl Default for Compression {
    fn default() -> Self {
        Compression(6)
    }
}

const MIN_MATCH: usize = 4;
const HASH_BITS: u32 = 16;

#[inline]
fn hash4(key: u32) -> usize {
    (key.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

#[inline]
fn read_u32(data: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]])
}

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            break;
        }
        out.push(byte | 0x80);
    }
}

fn get_varint(data: &[u8], pos: &mut usize) -> io::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = *data
            .get(*pos)
            .ok_or_else(|| io::Error::new(io::ErrorKind::UnexpectedEof, "deflate: truncated varint"))?;
        *pos += 1;
        if shift >= 64 {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "deflate: varint overflow"));
        }
        v |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Greedy single-pass LZ compression.
fn compress(data: &[u8]) -> Vec<u8> {
    let n = data.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    let mut table = vec![u32::MAX; 1 << HASH_BITS];
    let mut i = 0usize;
    let mut lit_start = 0usize;

    let flush_literals = |out: &mut Vec<u8>, lo: usize, hi: usize| {
        if hi > lo {
            out.push(0x00);
            put_varint(out, (hi - lo) as u64);
            out.extend_from_slice(&data[lo..hi]);
        }
    };

    while i < n {
        if i + MIN_MATCH <= n {
            let key = read_u32(data, i);
            let h = hash4(key);
            let cand = table[h];
            table[h] = i as u32;
            if cand != u32::MAX {
                let c = cand as usize;
                if c < i && read_u32(data, c) == key {
                    // Extend the match; overlap with the current position
                    // is fine (the decoder copies byte by byte).
                    let mut len = MIN_MATCH;
                    while i + len < n && data[c + len] == data[i + len] {
                        len += 1;
                    }
                    flush_literals(&mut out, lit_start, i);
                    out.push(0x01);
                    put_varint(&mut out, len as u64);
                    put_varint(&mut out, (i - c) as u64);
                    // Register positions inside the match so later data can
                    // still find them.
                    let end = i + len;
                    i += 1;
                    while i < end {
                        if i + MIN_MATCH <= n {
                            table[hash4(read_u32(data, i))] = i as u32;
                        }
                        i += 1;
                    }
                    lit_start = i;
                    continue;
                }
            }
        }
        i += 1;
    }
    flush_literals(&mut out, lit_start, n);
    out
}

fn decompress(data: &[u8]) -> io::Result<Vec<u8>> {
    let mut out = Vec::with_capacity(data.len() * 2);
    let mut pos = 0usize;
    while pos < data.len() {
        let tag = data[pos];
        pos += 1;
        match tag {
            0x00 => {
                let len = get_varint(data, &mut pos)? as usize;
                let end = pos.checked_add(len).filter(|&e| e <= data.len()).ok_or_else(|| {
                    io::Error::new(io::ErrorKind::InvalidData, "deflate: truncated literal run")
                })?;
                out.extend_from_slice(&data[pos..end]);
                pos = end;
            }
            0x01 => {
                let len = get_varint(data, &mut pos)? as usize;
                let dist = get_varint(data, &mut pos)? as usize;
                if dist == 0 || dist > out.len() {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "deflate: match distance out of range",
                    ));
                }
                if len > data.len().saturating_mul(256).max(1 << 24) {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "deflate: implausible match length",
                    ));
                }
                for _ in 0..len {
                    let b = out[out.len() - dist];
                    out.push(b);
                }
            }
            t => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("deflate: bad token tag {t:#x}"),
                ));
            }
        }
    }
    Ok(out)
}

pub mod write {
    use super::*;

    /// Buffering encoder with the upstream `flate2::write::DeflateEncoder`
    /// API: `Write` the body in, `finish()` yields the inner writer.
    pub struct DeflateEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> DeflateEncoder<W> {
        pub fn new(inner: W, _level: Compression) -> DeflateEncoder<W> {
            DeflateEncoder { inner, buf: Vec::new() }
        }

        pub fn finish(mut self) -> io::Result<W> {
            let compressed = compress(&self.buf);
            self.inner.write_all(&compressed)?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for DeflateEncoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use super::*;

    /// Decoder with the upstream `flate2::read::DeflateDecoder` API.
    /// Decompression happens on first read; errors surface as `io::Error`.
    pub struct DeflateDecoder<R: Read> {
        inner: Option<R>,
        out: Vec<u8>,
        pos: usize,
    }

    impl<R: Read> DeflateDecoder<R> {
        pub fn new(inner: R) -> DeflateDecoder<R> {
            DeflateDecoder { inner: Some(inner), out: Vec::new(), pos: 0 }
        }

        fn fill(&mut self) -> io::Result<()> {
            if let Some(mut r) = self.inner.take() {
                let mut raw = Vec::new();
                r.read_to_end(&mut raw)?;
                self.out = decompress(&raw)?;
                self.pos = 0;
            }
            Ok(())
        }
    }

    impl<R: Read> Read for DeflateDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.fill()?;
            let n = (self.out.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.out[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::read::DeflateDecoder;
    use super::write::DeflateEncoder;
    use super::*;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(data).unwrap();
        let compressed = enc.finish().unwrap();
        let mut dec = DeflateDecoder::new(compressed.as_slice());
        let mut out = Vec::new();
        dec.read_to_end(&mut out).unwrap();
        out
    }

    #[test]
    fn roundtrips_structured_and_random_ish_bodies() {
        for data in [
            Vec::new(),
            b"hello".to_vec(),
            b"abcabcabcabcabcabcabcabc".to_vec(),
            (0..10_000u32).map(|i| (i % 251) as u8).collect::<Vec<u8>>(),
            (0..5_000u32).map(|i| (i.wrapping_mul(2654435761) >> 24) as u8).collect::<Vec<u8>>(),
        ] {
            assert_eq!(roundtrip(&data), data);
        }
    }

    #[test]
    fn runs_compress_dramatically() {
        let data = vec![7u8; 100_000];
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&data).unwrap();
        let compressed = enc.finish().unwrap();
        assert!(compressed.len() * 100 < data.len(), "compressed to {}", compressed.len());
        let mut dec = DeflateDecoder::new(compressed.as_slice());
        let mut out = Vec::new();
        dec.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn overlapping_matches_decode() {
        // "aaaa" then a long overlapped copy with dist 1.
        let mut data = b"aaaa".to_vec();
        data.extend(std::iter::repeat(b'a').take(50));
        data.extend(b"tail");
        assert_eq!(roundtrip(&data), data);
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(b"some compressible payload payload payload").unwrap();
        let compressed = enc.finish().unwrap();
        for i in 0..compressed.len() {
            let mut bad = compressed.clone();
            bad[i] ^= 0xFF;
            // Either decodes to different bytes (caught by the slice CRC)
            // or errors — but never panics.
            let mut dec = DeflateDecoder::new(bad.as_slice());
            let mut out = Vec::new();
            let _ = dec.read_to_end(&mut out);
        }
        // Truncation must error or yield a short/different body.
        let mut dec = DeflateDecoder::new(&compressed[..compressed.len() / 2]);
        let mut out = Vec::new();
        let _ = dec.read_to_end(&mut out);
    }

    #[test]
    fn error_messages_carry_deflate_marker() {
        let bad = [0x02u8, 0x01];
        let mut dec = DeflateDecoder::new(bad.as_slice());
        let mut out = Vec::new();
        let err = dec.read_to_end(&mut out).unwrap_err();
        assert!(err.to_string().contains("deflate"), "{err}");
    }
}
