//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The real crate links the XLA C++ runtime, which is not available in
//! this image. This stand-in keeps `runtime/pjrt.rs` compiling and
//! *functional* by recognizing the two kernels this repo AOT-compiles
//! (`python/compile/kernels/`) from their artifact file names and
//! executing their documented semantics with plain CPU loops:
//!
//! * `pagerank_b{B}_k{K}`: `y[k,d] = Σ_s A[k,s,d] · x[k,s]`
//! * `minplus_b{B}_k{K}`:  `o[k,j] = min_s (d[k,s] + W[k,s,j])`
//!
//! Numerically these match the Pallas kernels (same reduction order per
//! element, f32 throughout), so the `pjrt_kernels_match_scalar_backends`
//! oracle tests remain meaningful. Swap the path dependency back to the
//! real `xla` crate to run on an actual PJRT client; the call sites do
//! not change.

use std::borrow::Borrow;
use std::fmt;
use std::path::{Path, PathBuf};

/// Error type with the Display surface `pjrt.rs` formats with `{e}`.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(XlaError(msg.into()))
}

/// Element types (only F32 is used by this repo).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Which builtin kernel an HLO artifact lowers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum KernelKind {
    PageRank,
    MinPlus,
}

/// Parsed handle to an HLO text artifact. The stand-in identifies the
/// kernel from the file name (`<name>_b<B>_k<K>.hlo.txt`), which is how
/// `python/compile/aot.py` names its outputs.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    kind: KernelKind,
    b: usize,
    k: usize,
    #[allow(dead_code)]
    path: PathBuf,
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        if !path.exists() {
            return err(format!("no such HLO artifact: {}", path.display()));
        }
        let stem = path
            .file_name()
            .and_then(|s| s.to_str())
            .map(|s| s.split('.').next().unwrap_or(s))
            .unwrap_or_default();
        let mut parts = stem.split('_');
        let name = parts.next().unwrap_or_default();
        let kind = match name {
            "pagerank" => KernelKind::PageRank,
            "minplus" => KernelKind::MinPlus,
            other => return err(format!("stand-in xla: unknown kernel family {other:?} in {stem}")),
        };
        let mut b = None;
        let mut k = None;
        for p in parts {
            if let Some(v) = p.strip_prefix('b') {
                b = v.parse().ok();
            } else if let Some(v) = p.strip_prefix('k') {
                k = v.parse().ok();
            }
        }
        match (b, k) {
            (Some(b), Some(k)) if b > 0 && k > 0 => {
                Ok(HloModuleProto { kind, b, k, path: path.to_path_buf() })
            }
            _ => err(format!("stand-in xla: cannot parse b/k from artifact name {stem:?}")),
        }
    }
}

/// A "computation" — carries the parsed kernel identity.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }
}

/// Host/device buffer (device == host here).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        // Executions return 1-tuples (aot.py lowers with return_tuple).
        Ok(Literal { data: self.data.clone(), shape: self.shape.clone(), tupled: true })
    }
}

/// A typed host literal.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    shape: Vec<usize>,
    tupled: bool,
}

/// Conversion support for `Literal::to_vec::<T>()` /
/// `buffer_from_host_buffer::<T>`.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
    fn into_f32(self) -> f32;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
    fn into_f32(self) -> f32 {
        self
    }
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        match ty {
            ElementType::F32 => {}
        }
        if data.len() % 4 != 0 {
            return err("untyped f32 data length not a multiple of 4");
        }
        let n: usize = shape.iter().product();
        if n * 4 != data.len() {
            return err(format!("shape {shape:?} does not match {} bytes", data.len()));
        }
        let floats: Vec<f32> = data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Literal { data: floats, shape: shape.to_vec(), tupled: false })
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        if !self.tupled {
            return err("literal is not a tuple");
        }
        Ok(Literal { tupled: false, ..self })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
}

/// Compiled executable: the kernel identity plus its (B, K) variant.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    kind: KernelKind,
    b: usize,
    k: usize,
}

impl PjRtLoadedExecutable {
    fn run(&self, a: (&[f32], &[usize]), x: (&[f32], &[usize])) -> Result<PjRtBuffer> {
        let (b, k) = (self.b, self.k);
        let (tiles, tiles_shape) = a;
        let (vec_in, vec_shape) = x;
        if tiles_shape != [k, b, b] {
            return err(format!("tile argument shape {tiles_shape:?} != [{k}, {b}, {b}]"));
        }
        if vec_shape != [k, b] {
            return err(format!("vector argument shape {vec_shape:?} != [{k}, {b}]"));
        }
        if tiles.len() != k * b * b || vec_in.len() != k * b {
            return err("argument data does not match its shape");
        }
        let mut out = vec![0.0f32; k * b];
        match self.kind {
            KernelKind::PageRank => {
                // y[k,d] = sum_s A[k,s,d] * x[k,s]
                for kk in 0..k {
                    let tile = &tiles[kk * b * b..(kk + 1) * b * b];
                    let xv = &vec_in[kk * b..(kk + 1) * b];
                    let yv = &mut out[kk * b..(kk + 1) * b];
                    for s in 0..b {
                        let xs = xv[s];
                        if xs == 0.0 {
                            continue;
                        }
                        let row = &tile[s * b..(s + 1) * b];
                        for d in 0..b {
                            yv[d] += row[d] * xs;
                        }
                    }
                }
            }
            KernelKind::MinPlus => {
                // o[k,j] = min_s (d[k,s] + W[k,s,j])
                for kk in 0..k {
                    let tile = &tiles[kk * b * b..(kk + 1) * b * b];
                    let dv = &vec_in[kk * b..(kk + 1) * b];
                    let ov = &mut out[kk * b..(kk + 1) * b];
                    for v in ov.iter_mut() {
                        *v = f32::INFINITY;
                    }
                    for s in 0..b {
                        let ds = dv[s];
                        let row = &tile[s * b..(s + 1) * b];
                        for j in 0..b {
                            let cand = ds + row[j];
                            if cand < ov[j] {
                                ov[j] = cand;
                            }
                        }
                    }
                }
            }
        }
        Ok(PjRtBuffer { data: out, shape: vec![k, b] })
    }

    fn classify<'s>(
        args: &[(&'s [f32], &'s [usize])],
    ) -> Result<((&'s [f32], &'s [usize]), (&'s [f32], &'s [usize]))> {
        if args.len() != 2 {
            return err(format!("expected 2 arguments, got {}", args.len()));
        }
        // Tile batch is the rank-3 argument, the vector is rank-2; accept
        // either order.
        match (args[0].1.len(), args[1].1.len()) {
            (3, 2) => Ok((args[0], args[1])),
            (2, 3) => Ok((args[1], args[0])),
            _ => err("expected one [K,B,B] and one [K,B] argument"),
        }
    }

    /// Execute with host literals.
    pub fn execute<T: Borrow<Literal>>(&self, args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let views: Vec<(&[f32], &[usize])> = args
            .iter()
            .map(|l| {
                let l = l.borrow();
                (l.data.as_slice(), l.shape.as_slice())
            })
            .collect();
        let (a, x) = Self::classify(&views)?;
        Ok(vec![vec![self.run(a, x)?]])
    }

    /// Execute with device-resident buffers.
    pub fn execute_b<T: Borrow<PjRtBuffer>>(&self, args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let views: Vec<(&[f32], &[usize])> = args
            .iter()
            .map(|l| {
                let l = l.borrow();
                (l.data.as_slice(), l.shape.as_slice())
            })
            .collect();
        let (a, x) = Self::classify(&views)?;
        Ok(vec![vec![self.run(a, x)?]])
    }
}

/// The "client": compiles computations and uploads buffers.
#[derive(Debug, Default)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        let p = &computation.proto;
        Ok(PjRtLoadedExecutable { kind: p.kind, b: p.b, k: p.k })
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return err(format!("shape {shape:?} != data length {}", data.len()));
        }
        Ok(PjRtBuffer {
            data: data.iter().map(|v| v.into_f32()).collect(),
            shape: shape.to_vec(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("xla-standin-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, "HloModule standin").unwrap();
        p
    }

    fn exe(name: &str) -> PjRtLoadedExecutable {
        let proto = HloModuleProto::from_text_file(&artifact(name)).unwrap();
        PjRtClient::cpu().unwrap().compile(&XlaComputation::from_proto(&proto)).unwrap()
    }

    fn literal(data: Vec<f32>, shape: Vec<usize>) -> Literal {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        Literal::create_from_shape_and_untyped_data(ElementType::F32, &shape, &bytes).unwrap()
    }

    #[test]
    fn pagerank_kernel_sums_products() {
        let e = exe("pagerank_b2_k1.hlo.txt");
        // A[0] = [[1, 2], [3, 4]] (rows = source s, cols = dest d), x = [10, 100].
        let a = literal(vec![1.0, 2.0, 3.0, 4.0], vec![1, 2, 2]);
        let x = literal(vec![10.0, 100.0], vec![1, 2]);
        let out = e.execute::<Literal>(&[a, x]).unwrap();
        let y = out[0][0].to_literal_sync().unwrap().to_tuple1().unwrap().to_vec::<f32>().unwrap();
        // y[d] = sum_s A[s,d]*x[s] -> y[0] = 1*10 + 3*100 = 310; y[1] = 2*10 + 4*100 = 420
        assert_eq!(y, vec![310.0, 420.0]);
    }

    #[test]
    fn minplus_kernel_takes_min_of_sums() {
        let e = exe("minplus_b2_k1.hlo.txt");
        let w = literal(vec![5.0, 1.0, 2.0, 9.0], vec![1, 2, 2]);
        let d = literal(vec![0.0, 10.0], vec![1, 2]);
        let out = e.execute::<Literal>(&[w, d]).unwrap();
        let o = out[0][0].to_literal_sync().unwrap().to_tuple1().unwrap().to_vec::<f32>().unwrap();
        // o[j] = min_s d[s] + W[s,j] -> o[0] = min(0+5, 10+2) = 5; o[1] = min(0+1, 10+9) = 1
        assert_eq!(o, vec![5.0, 1.0]);
    }

    #[test]
    fn session_buffers_match_literals() {
        let e = exe("pagerank_b2_k1.hlo.txt");
        let client = PjRtClient::cpu().unwrap();
        let a = client
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0, 3.0, 4.0], &[1, 2, 2], None)
            .unwrap();
        let x = client.buffer_from_host_buffer::<f32>(&[10.0, 100.0], &[1, 2], None).unwrap();
        let out = e.execute_b::<&PjRtBuffer>(&[&a, &x]).unwrap();
        let y = out[0][0].to_literal_sync().unwrap().to_tuple1().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(y, vec![310.0, 420.0]);
    }

    #[test]
    fn unknown_artifact_names_error() {
        assert!(HloModuleProto::from_text_file(&artifact("mystery_b8_k2.hlo.txt")).is_err());
        assert!(HloModuleProto::from_text_file(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }
}
