//! Fig. 7 — time per iBSP timestep for the SSSP application.
//!
//! "The Y axis shows the total time taken by one BSP while the X axis
//! shows sequentially increasing instances, with the first 11 shown."
//! Configurations: s20-i20-c0, s20-i1-c14, s20-i20-c14. Expected shapes:
//! timestep 0 dominates (template load, done once); the no-cache config
//! pays a visible penalty; packing differences are muted because SSSP is
//! compute-bound.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::apps::SsspApp;
use goffish::datagen::{traceroute, CollectionSource};
use goffish::gopher::RunOptions;
use goffish::util::bench::{BenchArgs, Table};

fn main() {
    let args = BenchArgs::from_env();
    let scale = BenchScale::from_args(&args);
    let n_ts = args.usize("timesteps", 11).min(scale.instances);
    let gen = scale.generator();
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];

    // Paper's three configs, plus s20-i20-c28: with c14 < s20 bins the LRU
    // cycles and temporal packing gets no cross-timestep reuse (a finding
    // of this reproduction); 28 slots >= bins shows the §V-C effect.
    let configs: Vec<(usize, usize, usize)> = vec![(20, 20, 0), (20, 1, 14), (20, 20, 14), (20, 20, 28)];
    let mut all: Vec<(String, Vec<f64>, f64)> = Vec::new(); // per-ts seconds + template load
    // (mean load wall, mean overlap) per config — the pipelined-loader
    // split added to TimestepStats.
    let mut load_splits: Vec<(String, (f64, f64))> = Vec::new();

    for &(bins, pack, cache) in &configs {
        let (dir, _) = deploy_cached(&gen, &scale, bins, pack);
        let t0 = std::time::Instant::now();
        let (eng, _metrics) = engine(&dir, scale.hosts, cache);
        // Template + metadata load happens at open; the paper folds it
        // into timestep 0 ("Timestep 0 includes template load time").
        let template_load_s = t0.elapsed().as_secs_f64()
            + eng.stores().iter().map(|s| s.sim_disk_ns()).sum::<u64>() as f64 / 1e9;

        let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
        let stats = eng
            .run(&app, &RunOptions { timesteps: Some((0..n_ts).collect()), ..Default::default() })
            .expect("sssp run");
        let per_ts: Vec<f64> = stats
            .per_timestep
            .iter()
            .map(|t| t.wall_s + t.sim_disk_ns as f64 / 1e9 + t.sim_net_ns as f64 / 1e9)
            .collect();
        let n = stats.per_timestep.len() as f64;
        load_splits.push((
            cfg_label(bins, pack, cache),
            (
                stats.per_timestep.iter().map(|t| t.load_wall_s).sum::<f64>() / n,
                stats.per_timestep.iter().map(|t| t.overlap_s).sum::<f64>() / n,
            ),
        ));
        all.push((cfg_label(bins, pack, cache), per_ts, template_load_s));
    }

    let mut fig7 = Table::new(
        &std::iter::once("timestep".to_string())
            .chain(all.iter().map(|(l, _, _)| format!("{l} (s)")))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>(),
    );
    for t in 0..n_ts {
        let mut row = vec![t.to_string()];
        for (_, per_ts, tmpl) in &all {
            let v = per_ts[t] + if t == 0 { *tmpl } else { 0.0 };
            row.push(format!("{v:.3}"));
        }
        fig7.row(&row);
    }
    fig7.print("Fig. 7 — time per iBSP SSSP timestep (modeled disk+net included)");

    // Shape checks.
    for (label, per_ts, tmpl) in &all {
        let t0 = per_ts[0] + tmpl;
        let rest: f64 = per_ts[1..].iter().sum::<f64>() / (per_ts.len() - 1) as f64;
        println!("shape [{label}]: timestep0 = {t0:.3}s vs later mean {rest:.3}s (t0 dominates: {})",
            t0 > rest);
    }
    for (label, load) in &load_splits {
        println!(
            "load split [{label}]: {:.1} ms load wall/timestep, {:.1} ms overlapped by prefetch, {:.1} ms blocking",
            load.0 * 1e3,
            load.1 * 1e3,
            (load.0 - load.1).max(0.0) * 1e3
        );
    }
    let t_c0: f64 = all[0].1[1..].iter().sum();
    let t_c14: f64 = all[2].1[1..].iter().sum();
    println!("shape: no-cache penalty over timesteps 1..: {:.2}x (>1 expected)", t_c0 / t_c14);
}
