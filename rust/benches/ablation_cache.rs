//! Ablation A1 — slice-cache size sweep (§V-E: "the cache size is
//! configurable and has to balance memory needs with access locality").
//!
//! Sweeps c over a full-scan workload and the SSSP app; reports modeled
//! disk time, hit rate and evictions. Expected: a knee at c ≈ number of
//! attribute slices live per bin group (the paper's c14 = one slot per
//! attribute), flat beyond.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::apps::SsspApp;
use goffish::datagen::{traceroute, CollectionSource};
use goffish::gofs::Projection;
use goffish::gopher::RunOptions;
use goffish::metrics::Metrics;
use goffish::util::bench::{BenchArgs, Table};
use std::sync::Arc;

fn main() {
    let args = BenchArgs::from_env();
    let scale = BenchScale::from_args(&args);
    let gen = scale.generator();
    let (dir, _) = deploy_cached(&gen, &scale, 20, 20);
    let caches = [0usize, 2, 4, 8, 14, 28, 64];

    let mut scan_t = Table::new(&["cache", "scan sim disk (s)", "hits", "misses", "hit rate", "evictions"]);
    for &c in &caches {
        let stores = open_stores(&dir, scale.hosts, c, Arc::new(Metrics::new()));
        for store in &stores {
            let proj = Projection::all(store.vertex_schema(), store.edge_schema());
            for sg in store.subgraphs() {
                for t in 0..scale.instances {
                    let _ = store.read_instance(sg.id.local(), t, &proj).unwrap();
                }
            }
        }
        let sim: u64 = stores.iter().map(|s| s.sim_disk_ns()).sum();
        let (h, m, e) = stores.iter().fold((0, 0, 0), |acc, s| {
            let (h, m, e) = s.cache_stats();
            (acc.0 + h, acc.1 + m, acc.2 + e)
        });
        scan_t.row(&[
            format!("c{c}"),
            format!("{:.2}", sim as f64 / 1e9),
            h.to_string(),
            m.to_string(),
            format!("{:.1}%", 100.0 * h as f64 / (h + m).max(1) as f64),
            e.to_string(),
        ]);
    }
    scan_t.print("A1 — cache sweep, full scan (s20-i20)");

    let mut sssp_t = Table::new(&["cache", "sssp total (s)", "slices read"]);
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    for &c in &caches {
        let (eng, _m) = engine(&dir, scale.hosts, c);
        let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
        let stats = eng
            .run(&app, &RunOptions { timesteps: Some((0..8).collect()), ..Default::default() })
            .unwrap();
        let total: f64 = stats
            .per_timestep
            .iter()
            .map(|t| t.wall_s + t.sim_disk_ns as f64 / 1e9)
            .sum();
        let slices: u64 = stats.per_timestep.iter().map(|t| t.slices_read).sum();
        sssp_t.row(&[format!("c{c}"), format!("{total:.2}"), slices.to_string()]);
    }
    sssp_t.print("A1 — cache sweep, iBSP SSSP (8 timesteps, s20-i20)");
}
