//! Fig. 8 — cumulative slices loaded vs. timestep for the iBSP SSSP run.
//!
//! "The lack of caching shows the high slope for s20-i20-c0, while we see
//! a tangible difference in the number of slices read with and without
//! temporal packing." Same three configurations as Fig. 7.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::apps::SsspApp;
use goffish::datagen::{traceroute, CollectionSource};
use goffish::gopher::RunOptions;
use goffish::util::bench::{BenchArgs, Table};

fn main() {
    let args = BenchArgs::from_env();
    let scale = BenchScale::from_args(&args);
    let n_ts = args.usize("timesteps", 11).min(scale.instances);
    let gen = scale.generator();
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];

    // Paper's three configs, plus s20-i20-c28: with c14 < s20 bins the LRU
    // cycles and temporal packing gets no cross-timestep reuse (a finding
    // of this reproduction); 28 slots >= bins shows the §V-C effect.
    let configs: Vec<(usize, usize, usize)> = vec![(20, 20, 0), (20, 1, 14), (20, 20, 14), (20, 20, 28)];
    let mut all: Vec<(String, Vec<u64>)> = Vec::new();

    for &(bins, pack, cache) in &configs {
        let (dir, _) = deploy_cached(&gen, &scale, bins, pack);
        let (eng, _metrics) = engine(&dir, scale.hosts, cache);
        let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
        let stats = eng
            .run(&app, &RunOptions { timesteps: Some((0..n_ts).collect()), ..Default::default() })
            .expect("sssp run");
        let mut cum = Vec::with_capacity(n_ts);
        let mut acc = 0u64;
        for t in &stats.per_timestep {
            acc += t.slices_read;
            cum.push(acc);
        }
        all.push((cfg_label(bins, pack, cache), cum));
    }

    let mut fig8 = Table::new(
        &std::iter::once("timestep".to_string())
            .chain(all.iter().map(|(l, _)| format!("{l} slices")))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>(),
    );
    for t in 0..n_ts {
        let mut row = vec![t.to_string()];
        for (_, cum) in &all {
            row.push(cum[t].to_string());
        }
        fig8.row(&row);
    }
    fig8.print("Fig. 8 — cumulative slices loaded per timestep (iBSP SSSP)");

    let last = n_ts - 1;
    let by = |l: &str| all.iter().find(|(x, _)| x == l).unwrap().1[last];
    println!(
        "\nshape: slope c0/c14 = {:.2}x (steepest expected for c0); i1-c14/i20-c14 = {:.2}x; \
         i1-c14/i20-c28 = {:.2}x (packing pays once cache >= bins)",
        by("s20-i20-c0") as f64 / by("s20-i20-c14") as f64,
        by("s20-i1-c14") as f64 / by("s20-i20-c14") as f64,
        by("s20-i1-c14") as f64 / by("s20-i20-c28") as f64,
    );
}
