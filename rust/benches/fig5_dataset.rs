//! Fig. 5 (+ §VI-A stats): dataset characterization.
//!
//! (a) frequency distribution of vertices & edges per subgraph (log2
//! buckets), (b) number of subgraphs per partition, plus the dataset
//! stats table (vertices, edges, diameter, instance count). Paper shape:
//! power-law subgraph sizes spanning ~1 to ~30% of the graph; 1-285
//! subgraphs per partition with an inverse size correlation.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::datagen::CollectionSource;
use goffish::util::bench::{BenchArgs, Table};
use goffish::util::histogram::LogHistogram;

fn main() {
    let args = BenchArgs::from_env();
    let scale = BenchScale::from_args(&args);
    let gen = scale.generator();
    let (_, report) = deploy_cached(&gen, &scale, 20, 20);

    // --- §VI-A dataset stats table (E5). ---
    let mut stats = Table::new(&["metric", "paper (TR)", "this run (synthetic TR)"]);
    let t = gen.template();
    stats.row(&["vertices".into(), "19,442,778".into(), t.n_vertices().to_string()]);
    stats.row(&["edges".into(), "22,782,842".into(), t.n_edges().to_string()]);
    stats.row(&[
        "edge:vertex ratio".into(),
        "1.17".into(),
        format!("{:.2}", t.n_edges() as f64 / t.n_vertices() as f64),
    ]);
    stats.row(&["diameter".into(), "25".into(), t.estimate_diameter(0).to_string()]);
    stats.row(&["instances".into(), "146".into(), gen.n_instances().to_string()]);
    stats.row(&["vertex/edge attrs".into(), "7 / 7".into(), format!(
        "{} / {}",
        t.vertex_schema.len(),
        t.edge_schema.len()
    )]);
    stats.row(&["partitions".into(), "12".into(), report.n_parts.to_string()]);
    stats.print("§VI-A dataset statistics (E5)");

    // --- Fig. 5(a): vertices & edges per subgraph, log-bucketed. ---
    let mut vh = LogHistogram::new();
    let mut eh = LogHistogram::new();
    for &(v, e) in &report.subgraph_sizes {
        vh.record(v as u64);
        eh.record(e as u64);
    }
    let mut fig5a = Table::new(&["size bucket [lo,hi)", "# subgraphs by |V|", "# subgraphs by |E|"]);
    let rows = vh.rows();
    let erows = eh.rows();
    for i in 0..rows.len().max(erows.len()) {
        let (lo, hi) = rows
            .get(i)
            .map(|r| (r.0, r.1))
            .or_else(|| erows.get(i).map(|r| (r.0, r.1)))
            .unwrap();
        let vc = rows.get(i).map(|r| r.2).unwrap_or(0);
        let ec = erows.get(i).map(|r| r.2).unwrap_or(0) + if i == 0 { eh.zeros() } else { 0 };
        fig5a.row(&[format!("[{lo}, {hi})"), vc.to_string(), ec.to_string()]);
    }
    fig5a.print("Fig. 5(a) — frequency distribution of vertices/edges per subgraph (log scale)");

    // --- Fig. 5(b): subgraphs per partition. ---
    let mut fig5b = Table::new(&["partition", "# subgraphs", "vertices", "largest subgraph |V|"]);
    let mut idx = 0usize;
    for (p, &count) in report.subgraphs_per_partition.iter().enumerate() {
        let slice = &report.subgraph_sizes[idx..idx + count];
        idx += count;
        let verts: usize = slice.iter().map(|s| s.0).sum();
        let largest = slice.iter().map(|s| s.0).max().unwrap_or(0);
        fig5b.row(&[p.to_string(), count.to_string(), verts.to_string(), largest.to_string()]);
    }
    fig5b.print("Fig. 5(b) — subgraphs per partition");

    let min = report.subgraphs_per_partition.iter().min().unwrap();
    let max = report.subgraphs_per_partition.iter().max().unwrap();
    println!(
        "shape check: subgraphs/partition ranges {min}..{max} (paper: 1..285); \
         size skew max/median |V| = {:.0}x",
        {
            let mut vs: Vec<usize> = report.subgraph_sizes.iter().map(|s| s.0).collect();
            vs.sort_unstable();
            let median = vs[vs.len() / 2].max(1);
            *vs.last().unwrap() as f64 / median as f64
        }
    );
}
