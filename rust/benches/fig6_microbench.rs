//! Fig. 6 — GoFS layout micro-benchmark.
//!
//! "For each of the deployments, we scan through all the sub-graphs, and
//! for each, we load all their instances. We then sum the total read time
//! for all instances for each sub-graph, and plot this total read time
//! cumulatively for all the sub-graphs [sorted largest to smallest]."
//!
//! Series: {s20,s40} × {i1,i20} with c14, plus s20-i20-c0. Expected
//! shapes (paper §VI-B): i20 loses on the largest subgraphs but wins past
//! a cross-over (~80th subgraph for s20); 20 bins beat 40 bins, more so
//! without temporal packing; c0 ends ~3× above c14.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::gofs::{Projection, Store};
use goffish::metrics::Metrics;
use goffish::util::bench::{BenchArgs, Table};
use std::sync::Arc;

/// Scan: per subgraph (bin-major for locality), read all instances with a
/// full projection; return per-subgraph total modeled read time (ns) and
/// subgraph weight for sorting, plus wall seconds.
fn scan(stores: &[Store], instances: usize) -> (Vec<(usize, u64)>, f64, u64) {
    let mut per_sg: Vec<(usize, u64)> = Vec::new();
    let t0 = std::time::Instant::now();
    let mut slices = 0u64;
    for store in stores {
        let proj = Projection::all(store.vertex_schema(), store.edge_schema());
        for sg in store.subgraphs() {
            let before = store.sim_disk_ns();
            let s0 = store.cache_stats().1;
            for t in 0..instances {
                let _ = store.read_instance(sg.id.local(), t, &proj).expect("read");
            }
            per_sg.push((sg.weight(), store.sim_disk_ns() - before));
            slices += store.cache_stats().1 - s0;
        }
    }
    (per_sg, t0.elapsed().as_secs_f64(), slices)
}

fn main() {
    let args = BenchArgs::from_env();
    let scale = BenchScale::from_args(&args);
    let gen = scale.generator();

    // (bins, pack, cache) per paper configuration.
    let configs: Vec<(usize, usize, usize)> = vec![
        (20, 20, 14),
        (20, 1, 14),
        (40, 20, 14),
        (40, 1, 14),
        (20, 20, 0),
    ];

    let mut series: Vec<(String, Vec<u64>)> = Vec::new(); // cumulative ns per rank
    let mut totals = Table::new(&["config", "total modeled read (s)", "wall (s)", "slice reads"]);
    for &(bins, pack, cache) in &configs {
        let (dir, _) = deploy_cached(&gen, &scale, bins, pack);
        let stores = open_stores(&dir, scale.hosts, cache, Arc::new(Metrics::new()));
        let (mut per_sg, wall, slices) = scan(&stores, scale.instances);
        // Sort largest -> smallest subgraph, cumulative sum of read time.
        per_sg.sort_by_key(|&(w, _)| std::cmp::Reverse(w));
        let mut cum = Vec::with_capacity(per_sg.len());
        let mut acc = 0u64;
        for &(_, ns) in &per_sg {
            acc += ns;
            cum.push(acc);
        }
        let label = cfg_label(bins, pack, cache);
        totals.row(&[
            label.clone(),
            format!("{:.2}", acc as f64 / 1e9),
            format!("{wall:.2}"),
            slices.to_string(),
        ]);
        series.push((label, cum));
    }

    // Print the cumulative curves at log-spaced X (subgraph rank).
    let n = series[0].1.len();
    let mut xs: Vec<usize> = vec![1, 2, 5, 10, 20, 40, 80, 160, 320, 640];
    xs.retain(|&x| x <= n);
    if *xs.last().unwrap_or(&0) != n {
        xs.push(n);
    }
    let mut fig6 = Table::new(
        &std::iter::once("x = #subgraphs".to_string())
            .chain(series.iter().map(|(l, _)| format!("{l} (s)")))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>(),
    );
    for &x in &xs {
        let mut row = vec![x.to_string()];
        for (_, cum) in &series {
            row.push(format!("{:.3}", cum[x - 1] as f64 / 1e9));
        }
        fig6.row(&row);
    }
    fig6.print("Fig. 6 — cumulative modeled read time, subgraphs sorted largest→smallest");
    totals.print("Fig. 6 totals");

    // Shape checks (paper prose).
    let get = |label: &str| &series.iter().find(|(l, _)| l == label).unwrap().1;
    let (p20, np20) = (get("s20-i20-c14"), get("s20-i1-c14"));
    let crossover = (0..n).find(|&i| p20[i] < np20[i]);
    println!(
        "\nshape: i20-vs-i1 crossover at subgraph #{:?} (paper: ~80); ",
        crossover.map(|c| c + 1)
    );
    let (c0, c14) = (get("s20-i20-c0"), get("s20-i20-c14"));
    println!(
        "shape: c0/c14 total ratio = {:.2}x (paper: ~3x); s40-i1/s20-i1 = {:.2}x (>1 expected)",
        c0[n - 1] as f64 / c14[n - 1] as f64,
        get("s40-i1-c14")[n - 1] as f64 / np20[n - 1] as f64,
    );
}
