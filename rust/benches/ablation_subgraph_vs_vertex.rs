//! Ablation A4 — sub-graph-centric vs vertex-centric (paper §II, [6]).
//!
//! "By using a subgraph as a unit of computation [...] the number of
//! messages the framework must handle is dramatically reduced [...] and
//! thus requires fewer supersteps." We run SSSP and WCC through both the
//! Gopher engine and the Pregel-style vertex-centric baseline over the
//! SAME template and partitioning, and compare supersteps + messages.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::apps::{SsspApp, WccApp};
use goffish::datagen::{traceroute, CollectionSource};
use goffish::gopher::vertex_centric::{run_vertex_centric, undirected_of, VcSssp, VcWcc};
use goffish::gopher::RunOptions;
use goffish::partition::{partition_graph, PartitionOptions};
use goffish::util::bench::{BenchArgs, Table};

fn main() {
    let args = BenchArgs::from_env();
    let mut scale = BenchScale::from_args(&args);
    // Vertex-centric is O(V) per superstep in this in-memory baseline;
    // keep the default comparison modest.
    if !args.flag("full") {
        scale.vertices = scale.vertices.min(20_000);
    }
    let gen = scale.generator();
    let template = gen.template();
    let partitioning = partition_graph(template, &PartitionOptions::new(scale.hosts));
    let source_idx = gen.vantages()[0];
    let source_ext = template.ext_ids[source_idx as usize];

    let mut t = Table::new(&[
        "algorithm", "model", "supersteps", "msgs local", "msgs remote", "msg MB", "wall (s)",
    ]);

    // --- SSSP ---
    let t0 = std::time::Instant::now();
    let (_, vc) = run_vertex_centric(&VcSssp { source: source_idx }, template, &partitioning, 10_000);
    t.row(&[
        "sssp".into(),
        "vertex-centric".into(),
        vc.supersteps.to_string(),
        vc.msgs_local.to_string(),
        vc.msgs_remote.to_string(),
        format!("{:.2}", vc.msg_bytes as f64 / 1e6),
        format!("{:.2}", t0.elapsed().as_secs_f64()),
    ]);

    let (dir, _) = deploy_cached(&gen, &scale, 20, 20);
    let (eng, _m) = engine(&dir, scale.hosts, 14);
    let t0 = std::time::Instant::now();
    let app = SsspApp::new(source_ext, traceroute::eattr::LATENCY_MS);
    let stats = eng
        .run(&app, &RunOptions { timesteps: Some(vec![0]), ..Default::default() })
        .unwrap();
    let ts = &stats.per_timestep[0];
    t.row(&[
        "sssp".into(),
        "subgraph-centric".into(),
        ts.supersteps.to_string(),
        ts.msgs_local.to_string(),
        ts.msgs_remote.to_string(),
        format!("{:.2}", ts.msg_bytes_remote as f64 / 1e6),
        format!("{:.2}", t0.elapsed().as_secs_f64()),
    ]);

    // --- WCC ---
    let t0 = std::time::Instant::now();
    let undirected = std::sync::Arc::new(undirected_of(template));
    let (_, vc) = run_vertex_centric(&VcWcc { undirected }, template, &partitioning, 10_000);
    t.row(&[
        "wcc".into(),
        "vertex-centric".into(),
        vc.supersteps.to_string(),
        vc.msgs_local.to_string(),
        vc.msgs_remote.to_string(),
        format!("{:.2}", vc.msg_bytes as f64 / 1e6),
        format!("{:.2}", t0.elapsed().as_secs_f64()),
    ]);

    let t0 = std::time::Instant::now();
    let app = WccApp::new();
    let stats = eng
        .run(&app, &RunOptions { timesteps: Some(vec![0]), ..Default::default() })
        .unwrap();
    let ts = &stats.per_timestep[0];
    t.row(&[
        "wcc".into(),
        "subgraph-centric".into(),
        ts.supersteps.to_string(),
        ts.msgs_local.to_string(),
        ts.msgs_remote.to_string(),
        format!("{:.2}", ts.msg_bytes_remote as f64 / 1e6),
        format!("{:.2}", t0.elapsed().as_secs_f64()),
    ]);

    t.print("A4 — subgraph-centric vs vertex-centric (same template + partitioning)");
    println!("expected shape: subgraph-centric needs ~10-100x fewer supersteps and messages");
}
