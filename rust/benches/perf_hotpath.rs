//! P1 — hot-path microbenchmarks for the §Perf optimization loop.
//!
//! Measures each layer's critical operation in isolation so before/after
//! deltas in EXPERIMENTS.md §Perf are attributable:
//!   L3: slice decode, cache hit path, superstep barrier overhead,
//!       message routing, v1-vs-v2 attribute codec (bytes on disk,
//!       decode ns/column, typed-access ns/edge), pipelined loading;
//!   L1/L2 via PJRT: kernel dispatch latency + tile throughput vs the
//!       scalar backend at several subgraph sizes.
//!
//! Besides the human-readable tables, emits `BENCH_hotpath.json` (cwd, or
//! `--json PATH`) with the machine-readable series CI tracks over time.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::apps::SsspApp;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{
    compact_collection, deploy, deploy_template, CollectionAppender, CompactOptions,
    DeployConfig, IngestOptions, Projection, ReadTrace, SliceFile,
};
use goffish::graph::Schema;
use goffish::gopher::{
    Application, ComputeCtx, GopherEngine, Pattern, Payload, RunOptions, RunStats,
    SubgraphProgram,
};
use goffish::metrics::{keys, Metrics};
use goffish::partition::Subgraph;
use goffish::runtime::pjrt::{PjrtBackend, PjrtEngine};
use goffish::runtime::{LocalSpmv, ScalarBackend};
use goffish::util::bench::{BenchArgs, Bencher, Table};
use goffish::util::Prng;
use std::path::PathBuf;
use std::sync::Arc;

/// No-op app used to time pure engine overhead.
struct NoopApp {
    supersteps: usize,
}
struct NoopProgram {
    supersteps: usize,
}
impl SubgraphProgram for NoopProgram {
    fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &goffish::gofs::SubgraphInstance, _msgs: &[Payload]) {
        if ctx.superstep >= self.supersteps {
            ctx.vote_to_halt();
        }
    }
}
impl Application for NoopApp {
    fn name(&self) -> &str {
        "noop"
    }
    fn pattern(&self) -> Pattern {
        Pattern::Sequential
    }
    fn projection(&self, _: &Schema, _: &Schema) -> Projection {
        Projection::none()
    }
    fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
        Box::new(NoopProgram { supersteps: self.supersteps })
    }
}

/// Run temporal SSSP over `dir`, returning stats plus a quantized output
/// fingerprint (sorted (sgid, vertex-key, q-distance)).
fn sssp_fingerprint(
    dir: &PathBuf,
    hosts: usize,
    source: u64,
    n_ts: usize,
    prefetch: bool,
    workers: usize,
    overlap_routing: bool,
) -> (RunStats, Vec<(u64, usize, i64)>) {
    let (eng, _m) = engine(dir, hosts, 28);
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    let stats = eng
        .run(
            &app,
            &RunOptions {
                timesteps: Some((0..n_ts).collect()),
                prefetch,
                workers,
                overlap_routing,
                ..Default::default()
            },
        )
        .expect("sssp run");
    let distances = app.results.distances.lock().unwrap();
    let mut fp: Vec<(u64, usize, i64)> = distances
        .iter()
        .flat_map(|(sgid, (t, d))| {
            d.iter().enumerate().map(move |(lv, &x)| {
                let q = if x.is_finite() { (x as f64 * 1e4).round() as i64 } else { -1 };
                (sgid.0, *t * 1_000_000 + lv, q)
            })
        })
        .collect();
    fp.sort_unstable();
    (stats, fp)
}

fn main() {
    let args = BenchArgs::from_env();
    let scale = BenchScale::from_args(&args);
    let gen = scale.generator();
    let (dir, _) = deploy_cached(&gen, &scale, 20, 20);
    let b = Bencher::new(1, args.usize("iters", 5));
    let mut report = Table::new(&["probe", "value", "unit"]);
    let mut json: Vec<(String, f64)> = Vec::new();

    // --- L3: slice decode throughput. ---
    let sample = {
        // find a reasonably sized attribute slice
        let mut best: Option<(std::path::PathBuf, u64)> = None;
        let mut stack = vec![dir.join("part-0/attr")];
        while let Some(d) = stack.pop() {
            for e in std::fs::read_dir(&d).unwrap() {
                let e = e.unwrap();
                if e.path().is_dir() {
                    stack.push(e.path());
                } else {
                    let len = e.metadata().unwrap().len();
                    if best.as_ref().map(|(_, l)| len > *l).unwrap_or(true) {
                        best = Some((e.path(), len));
                    }
                }
            }
        }
        best.unwrap()
    };
    let bytes = std::fs::read(&sample.0).unwrap();
    let stats = b.bench("slice decode", || SliceFile::from_bytes(&bytes).unwrap());
    report.row(&[
        "slice decode".into(),
        format!("{:.1}", sample.1 as f64 / stats.min() / 1e6),
        "MB/s (on-disk bytes)".into(),
    ]);
    json.push(("slice_container_decode_mbps".into(), sample.1 as f64 / stats.min() / 1e6));

    // --- L3: cache hit path. ---
    let stores = open_stores(&dir, 1, 64, Arc::new(Metrics::new()));
    let store = &stores[0];
    let proj = Projection::all(store.vertex_schema(), store.edge_schema());
    let sg0 = store.subgraphs()[0].id.local();
    let _ = store.read_instance(sg0, 0, &proj).unwrap(); // warm
    let stats = b.bench("cached read_instance", || store.read_instance(sg0, 0, &proj).unwrap());
    report.row(&[
        "cached read_instance".into(),
        format!("{:.1}", stats.min() * 1e6),
        "us".into(),
    ]);
    json.push(("cached_read_instance_us".into(), stats.min() * 1e6));

    // --- L3: v1 vs v2 attribute slice format (tentpole probe). ---
    // Fresh small deployments in both formats: bytes on disk, cold decode
    // per column, typed access per edge, and identical SSSP outputs.
    {
        let mini_gen = TraceRouteGenerator::new(TraceRouteParams {
            n_vertices: scale.vertices.min(10_000),
            n_instances: scale.instances.min(12),
            traces_per_instance: scale.traces.min(800),
            ..Default::default()
        });
        let mini_hosts = 4usize;
        let mini_ts = mini_gen.n_instances();
        let deploy_mini = |version: u8| -> (PathBuf, u64, u64) {
            let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .join("target/bench-deployments")
                .join(format!("hotpath-codec-f{version}"));
            let _ = std::fs::remove_dir_all(&root);
            let mut cfg = DeployConfig::new(mini_hosts, 20, 20);
            cfg.slice_version = version;
            let rep = deploy(&mini_gen, &cfg, &root).expect("mini deploy");
            (root, rep.bytes_written, rep.attr_body_bytes)
        };
        let (d1, disk1, body1) = deploy_mini(1);
        let (d2, disk2, body2) = deploy_mini(2);
        let body_ratio = body1 as f64 / body2.max(1) as f64;
        report.row(&[
            "attr body bytes v1 -> v2".into(),
            format!("{:.2} -> {:.2} MB ({body_ratio:.2}x)", body1 as f64 / 1e6, body2 as f64 / 1e6),
            "uncompressed bodies".into(),
        ]);
        report.row(&[
            "deployment on disk v1 -> v2".into(),
            format!("{:.2} -> {:.2} MB", disk1 as f64 / 1e6, disk2 as f64 / 1e6),
            "deflated slices".into(),
        ]);
        json.push(("attr_body_bytes_v1".into(), body1 as f64));
        json.push(("attr_body_bytes_v2".into(), body2 as f64));
        json.push(("attr_body_reduction_x".into(), body_ratio));
        json.push(("bytes_on_disk_v1".into(), disk1 as f64));
        json.push(("bytes_on_disk_v2".into(), disk2 as f64));

        // Cold decode cost per attribute column (cache off: every
        // read_instance re-reads + decodes its projected slices).
        for (tag, d) in [("v1", &d1), ("v2", &d2)] {
            let metrics = Arc::new(Metrics::new());
            let stores = open_stores(d, mini_hosts, 0, metrics.clone());
            let m0 = metrics.snapshot();
            let (_, wall) = Bencher::once(|| {
                for s in &stores {
                    for sg in s.subgraphs() {
                        let p = Projection::all(s.vertex_schema(), s.edge_schema());
                        for t in 0..mini_ts.min(4) {
                            let _ = s.read_instance(sg.id.local(), t, &p).unwrap();
                        }
                    }
                }
            });
            let cols = metrics.snapshot().since(&m0).get(keys::SLICES_READ).max(1);
            let ns_per_col = wall * 1e9 / cols as f64;
            report.row(&[
                format!("cold column read+decode ({tag})"),
                format!("{:.1}", ns_per_col / 1e3),
                format!("us/column ({cols} columns)"),
            ]);
            json.push((format!("decode_ns_per_column_{tag}"), ns_per_col));
        }

        // Typed access: mean latency over every owned edge, warm cache.
        for (tag, d) in [("v1", &d1), ("v2", &d2)] {
            let stores = open_stores(d, mini_hosts, 64, Arc::new(Metrics::new()));
            let mut insts = Vec::new();
            let mut n_edges = 0usize;
            for s in &stores {
                let p = Projection::all(s.vertex_schema(), s.edge_schema());
                for sg in s.subgraphs() {
                    n_edges += sg.edges.len();
                    insts.push(s.read_instance(sg.id.local(), 0, &p).unwrap());
                }
            }
            let stats = b.bench(&format!("edge access {tag}"), || {
                let mut acc = 0.0f64;
                for sgi in &insts {
                    for e in 0..sgi.sg.edges.len() {
                        if let Some(x) = sgi.edge_f64(traceroute::eattr::LATENCY_MS, e) {
                            acc += x;
                        }
                    }
                }
                acc
            });
            let ns_per_edge = stats.min() * 1e9 / n_edges.max(1) as f64;
            report.row(&[
                format!("edge_f64 access ({tag})"),
                format!("{ns_per_edge:.1}"),
                format!("ns/edge ({n_edges} edges, warm)"),
            ]);
            json.push((format!("access_ns_per_edge_{tag}"), ns_per_edge));
        }

        // Outputs must be bit-identical across formats and prefetch modes.
        let src = mini_gen.template().ext_ids[mini_gen.vantages()[0] as usize];
        let n_ts = mini_ts.min(6);
        let workers = RunOptions::default().workers;
        let (_, fp_v1) = sssp_fingerprint(&d1, mini_hosts, src, n_ts, true, workers, true);
        let (_, fp_v2) = sssp_fingerprint(&d2, mini_hosts, src, n_ts, true, workers, true);
        let (_, fp_v2_np) = sssp_fingerprint(&d2, mini_hosts, src, n_ts, false, 1, true);
        assert_eq!(fp_v1, fp_v2, "v1/v2 slice formats changed SSSP outputs");
        assert_eq!(fp_v2, fp_v2_np, "prefetch changed SSSP outputs");
        println!(
            "codec probe: v1/v2 SSSP outputs identical; body bytes {body1} -> {body2} ({body_ratio:.2}x)"
        );
        let _ = std::fs::remove_dir_all(&d1);
        let _ = std::fs::remove_dir_all(&d2);
    }

    // --- L3: zero-copy cell slabs (tentpole probe). ---
    // One packed v2 group shaped like the traceroute edge-latency column
    // (quantized floats, a handful of values per present cell). "Warm"
    // means the slice bytes are resident; the probe measures the path
    // from a decoded position block to typed values in the app's hands —
    // the per-cell split (one sub_slab memcpy + alloc per cell on the
    // copying path, an offset view on the shared path) plus an
    // `f64_at` read of every element, i.e. the edge_f64 hot path.
    {
        use goffish::gofs::colcodec::{
            decode_pos_block, decode_pos_block_copied, encode_attr_body_v2, parse_v2_layout,
        };
        use goffish::graph::{AttrColumn, AttrType, AttrValue};
        let mut rng = Prng::new(0xC0FFEE);
        let n_ts = 20usize;
        let n_pos = 64usize;
        let cells: Vec<Vec<Option<AttrColumn>>> = (0..n_ts)
            .map(|t| {
                (0..n_pos)
                    .map(|p| {
                        if (t + p) % 5 == 0 {
                            return None; // absent cells, like real groups
                        }
                        let mut col = AttrColumn::new();
                        let n_elem = 4 + rng.gen_range(8) as usize;
                        let mut i = 0u32;
                        for _ in 0..n_elem {
                            i += 1 + rng.gen_range(3) as u32;
                            let v = rng.gen_range(1 << 14) as f64 / 1024.0;
                            col.push(i, [AttrValue::Float(v)]);
                        }
                        Some(col)
                    })
                    .collect()
            })
            .collect();
        let body = encode_attr_body_v2(&cells, AttrType::Float);
        let (_, _, ranges) = parse_v2_layout(&body).expect("v2 layout");
        let scan = |copied: bool| -> (f64, usize) {
            let mut acc = 0.0f64;
            let mut reads = 0usize;
            for &(lo, hi) in &ranges {
                let cols = if copied {
                    decode_pos_block_copied(&body[lo..hi], AttrType::Float, n_ts).unwrap()
                } else {
                    decode_pos_block(&body[lo..hi], AttrType::Float, n_ts).unwrap()
                };
                for c in cols.iter().flatten() {
                    for (i, _) in c.iter() {
                        acc += c.f64_at(i).unwrap_or(0.0);
                        reads += 1;
                    }
                }
            }
            (acc, reads)
        };
        // Both paths must agree value-for-value, and the shared path
        // must actually alias one slab per block.
        for &(lo, hi) in &ranges {
            let shared = decode_pos_block(&body[lo..hi], AttrType::Float, n_ts).unwrap();
            let copied = decode_pos_block_copied(&body[lo..hi], AttrType::Float, n_ts).unwrap();
            assert_eq!(shared, copied, "shared/copied cell decodes diverged");
            let present: Vec<&AttrColumn> = shared.iter().flatten().collect();
            for w in present.windows(2) {
                assert!(w[0].shares_backing(w[1]), "cells must share one slab");
            }
        }
        let (acc_s, n_reads) = scan(false);
        let (acc_c, n_reads_c) = scan(true);
        assert_eq!((acc_s.to_bits(), n_reads), (acc_c.to_bits(), n_reads_c));
        let shared_stats = b.bench("slab split+scan (shared)", || scan(false));
        let copied_stats = b.bench("slab split+scan (copied)", || scan(true));
        let ns_shared = shared_stats.min() * 1e9 / n_reads.max(1) as f64;
        let ns_copied = copied_stats.min() * 1e9 / n_reads.max(1) as f64;
        let speedup = ns_copied / ns_shared.max(1e-12);
        report.row(&[
            "edge_f64 warm (shared slab)".into(),
            format!("{ns_shared:.1}"),
            format!("ns/edge ({n_reads} reads, decode+scan)"),
        ]);
        report.row(&[
            "edge_f64 warm (copied slab)".into(),
            format!("{ns_copied:.1}"),
            "ns/edge (pre-zero-copy reference path)".into(),
        ]);
        report.row(&[
            "zero-copy slab speedup".into(),
            format!("{speedup:.2}x"),
            "copied/shared (>= 1.3x expected)".into(),
        ]);
        println!(
            "slab probe: {ns_copied:.1} -> {ns_shared:.1} ns/edge warm ({speedup:.2}x, \
             outputs identical)"
        );
        json.push(("edge_f64_ns_warm_shared".into(), ns_shared));
        json.push(("edge_f64_ns_warm_copied".into(), ns_copied));
        json.push(("slab_share_speedup_x".into(), speedup));
    }

    // --- L3: superstep barrier overhead (noop app, many supersteps). ---
    let (eng, _m) = engine(&dir, scale.hosts, 28);
    let supersteps = 50usize;
    let stats = b.bench("noop supersteps", || {
        eng.run(
            &NoopApp { supersteps },
            &RunOptions { timesteps: Some(vec![0]), ..Default::default() },
        )
        .unwrap()
    });
    let n_sg = eng.n_subgraphs();
    report.row(&[
        "superstep barrier+dispatch".into(),
        format!("{:.1}", stats.min() / supersteps as f64 * 1e6),
        format!("us/superstep ({n_sg} subgraphs)"),
    ]);
    json.push(("superstep_us".into(), stats.min() / supersteps as f64 * 1e6));

    // --- L3: message routing throughput. ---
    let routing = bench_message_routing(&eng, &b);
    report.row(&[
        "message routing".into(),
        format!("{:.2}", routing / 1e6),
        "M msgs/s".into(),
    ]);
    json.push(("routing_msgs_per_s".into(), routing));

    // --- L3: overlapped superstep routing (tentpole probe). ---
    // Message-heavy SSSP run with routing staged from compute workers
    // (default) vs the same staging run single-threaded at the barrier
    // (isolates the scheduling change, not an implementation
    // difference); outputs asserted bit-identical in the same probe,
    // per the determinism contract.
    {
        let n_ts = args.usize("timesteps", 8).min(scale.instances);
        let source = gen.template().ext_ids[gen.vantages()[0] as usize];
        let workers = RunOptions::default().workers;
        let (ov, fp_ov) = sssp_fingerprint(&dir, scale.hosts, source, n_ts, true, workers, true);
        let (sq, fp_sq) = sssp_fingerprint(&dir, scale.hosts, source, n_ts, true, workers, false);
        assert_eq!(fp_ov, fp_sq, "overlapped routing changed SSSP outputs");
        let supersteps = ov.total_supersteps().max(1) as f64;
        let route_ov_ms = ov.per_timestep.iter().map(|t| t.route_s).sum::<f64>() * 1e3;
        let route_sq_ms = sq.per_timestep.iter().map(|t| t.route_s).sum::<f64>() * 1e3;
        let overlap_s = ov.per_timestep.iter().map(|t| t.route_overlap_s).sum::<f64>();
        report.row(&[
            "route barrier (barrier-staged)".into(),
            format!("{:.3}", route_sq_ms / supersteps),
            "ms/superstep".into(),
        ]);
        report.row(&[
            "route barrier (overlapped)".into(),
            format!("{:.3}", route_ov_ms / supersteps),
            format!("ms/superstep ({:.3} ms staged under compute)", overlap_s * 1e3 / supersteps),
        ]);
        println!(
            "route probe: {:.3} -> {:.3} ms barrier/superstep, {:.2} ms routed under compute \
             (outputs identical)",
            route_sq_ms / supersteps,
            route_ov_ms / supersteps,
            overlap_s * 1e3
        );
        json.push(("route_ms_per_superstep_barrier".into(), route_sq_ms / supersteps));
        json.push(("route_ms_per_superstep".into(), route_ov_ms / supersteps));
        json.push(("route_overlap_s".into(), overlap_s));

        // Satellite probe: cross-host traffic volume from the per-host-
        // pair accounting (`TimestepStats::routed_pairs`) — what a real
        // transport puts on the wire, normalized per superstep.
        let routed_bpss = ov.total_routed_bytes() as f64 / supersteps;
        report.row(&[
            "routed bytes".into(),
            format!("{:.0}", routed_bpss),
            "B/superstep (per-host-pair accounting)".into(),
        ]);
        json.push(("routed_bytes_per_superstep".into(), routed_bpss));
    }

    // --- L3: pipelined instance loading (prefetch + parallel load). ---
    // Per-timestep *blocking* load wall time for the temporal SSSP app,
    // with the pipeline off (serial load on the driver thread, no
    // prefetch — the pre-pipelining engine) vs. on (default). App outputs
    // must be bit-identical; the acceptance bar is >= 1.5x.
    {
        let n_ts = args.usize("timesteps", 8).min(scale.instances);
        let source = gen.template().ext_ids[gen.vantages()[0] as usize];
        let (off, fp_off) = sssp_fingerprint(&dir, scale.hosts, source, n_ts, false, 1, true);
        let (on, fp_on) = sssp_fingerprint(
            &dir,
            scale.hosts,
            source,
            n_ts,
            true,
            RunOptions::default().workers,
            true,
        );
        assert_eq!(fp_off, fp_on, "prefetch/parallel load changed SSSP outputs");
        let block_off = off.total_load_blocking_s() / n_ts as f64;
        let block_on = on.total_load_blocking_s() / n_ts as f64;
        let overlap_on: f64 =
            on.per_timestep.iter().map(|t| t.overlap_s).sum::<f64>() / n_ts as f64;
        report.row(&[
            "load blocking (pipeline OFF)".into(),
            format!("{:.2}", block_off * 1e3),
            "ms/timestep (serial load, no prefetch)".into(),
        ]);
        report.row(&[
            "load blocking (pipeline ON)".into(),
            format!("{:.2}", block_on * 1e3),
            format!("ms/timestep (overlap {:.2} ms hidden)", overlap_on * 1e3),
        ]);
        let speedup = block_off / block_on.max(1e-9);
        report.row(&[
            "load pipeline speedup".into(),
            format!("{speedup:.2}x"),
            "blocking load, OFF/ON (>= 1.5x expected)".into(),
        ]);
        println!(
            "load pipeline: {:.2} -> {:.2} ms blocking load/timestep ({speedup:.2}x, outputs identical)",
            block_off * 1e3,
            block_on * 1e3
        );
        json.push(("blocking_load_ms_per_timestep_off".into(), block_off * 1e3));
        json.push(("blocking_load_ms_per_timestep_on".into(), block_on * 1e3));
        json.push(("load_pipeline_speedup_x".into(), speedup));
        json.push(("fig7_wall_s".into(), on.total_wall_s));
    }

    // --- L3: temporal-pool prefetch (tentpole probe). ---
    // PageRank (Independent pattern) over the temporal pool: shared
    // prefetch queue vs serial load-then-compute per worker; outputs
    // asserted identical, blocking-load split and overlap reported.
    {
        use goffish::apps::PageRankApp;
        let n_ts = args.usize("timesteps", 8).min(scale.instances);
        let run_pool = |prefetch: bool| {
            let (eng, _m) = engine(&dir, scale.hosts, 28);
            let app = PageRankApp::new(
                gen.template().n_vertices(),
                Some(traceroute::eattr::ACTIVE),
                Arc::new(ScalarBackend),
            );
            let stats = eng
                .run(
                    &app,
                    &RunOptions {
                        timesteps: Some((0..n_ts).collect()),
                        temporal_workers: 4,
                        prefetch,
                        ..Default::default()
                    },
                )
                .expect("pool run");
            let mut fp: Vec<(u64, i64)> = (0..n_ts)
                .flat_map(|t| {
                    app.results
                        .top_k(t, 10)
                        .into_iter()
                        .map(move |(v, r)| (v, (r as f64 * 1e12).round() as i64))
                })
                .collect();
            fp.sort_unstable();
            (stats, fp)
        };
        let (pool_off, fp_off) = run_pool(false);
        let (pool_on, fp_on) = run_pool(true);
        assert_eq!(fp_off, fp_on, "temporal-pool prefetch changed PageRank outputs");
        let block = |s: &RunStats| {
            s.per_timestep.iter().map(|t| t.load_blocking_s()).sum::<f64>() / n_ts as f64
        };
        let pool_overlap_s: f64 = pool_on.per_timestep.iter().map(|t| t.overlap_s).sum();
        report.row(&[
            "pool blocking load (serial)".into(),
            format!("{:.2}", block(&pool_off) * 1e3),
            "ms/timestep (load-then-compute per worker)".into(),
        ]);
        report.row(&[
            "pool blocking load (prefetch queue)".into(),
            format!("{:.2}", block(&pool_on) * 1e3),
            format!("ms/timestep ({:.2} ms load hidden)", pool_overlap_s * 1e3 / n_ts as f64),
        ]);
        println!(
            "pool probe: {:.2} -> {:.2} ms blocking load/timestep, {:.2} ms overlapped \
             (outputs identical)",
            block(&pool_off) * 1e3,
            block(&pool_on) * 1e3,
            pool_overlap_s * 1e3
        );
        json.push(("pool_blocking_load_ms_per_ts_off".into(), block(&pool_off) * 1e3));
        json.push(("pool_blocking_load_ms_per_ts_on".into(), block(&pool_on) * 1e3));
        json.push(("pool_load_overlap_s".into(), pool_overlap_s));
    }

    // --- L3: streaming ingest (WAL append -> seal -> follow). ---
    // Append throughput and seal latency on a fresh template-only
    // deployment, then follow-mode lag: how long after an append the
    // BSP actually computes that timestep.
    {
        let ing_gen = TraceRouteGenerator::new(TraceRouteParams {
            n_vertices: scale.vertices.min(10_000),
            n_instances: scale.instances.clamp(4, 12),
            traces_per_instance: scale.traces.min(800),
            ..Default::default()
        });
        let hosts = 2usize;
        let pack = 4usize;
        let n_inst = ing_gen.n_instances();
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("target/bench-deployments/hotpath-ingest");
        let _ = std::fs::remove_dir_all(&root);
        deploy_template(&ing_gen, &DeployConfig::new(hosts, 8, pack), &root)
            .expect("ingest probe: template deploy");

        let mut appender =
            CollectionAppender::open(&root, IngestOptions::default()).expect("appender");
        for t in 0..n_inst {
            appender.append(&ing_gen.instance(t)).expect("append");
        }
        let ing = appender.finish().expect("finish");
        let inst_per_s = ing.appended as f64 / ing.append_wall_s.max(1e-9);
        let seal_ms = ing.seal_wall_s * 1e3 / ing.sealed_groups.max(1) as f64;
        report.row(&[
            "ingest append".into(),
            format!("{inst_per_s:.1}"),
            format!("inst/s ({} instances, WAL fsync on)", ing.appended),
        ]);
        report.row(&[
            "ingest seal".into(),
            format!("{seal_ms:.2}"),
            format!("ms/group ({} groups of {pack})", ing.sealed_groups),
        ]);
        json.push(("ingest_append_inst_per_s".into(), inst_per_s));
        json.push(("ingest_seal_ms_per_group".into(), seal_ms));
        json.push(("ingest_wal_mb".into(), ing.wal_bytes as f64 / 1e6));

        // Satellite: WAL group commit — one fsync per 8 appends instead
        // of per append (seals still flush durably).
        let _ = std::fs::remove_dir_all(&root);
        deploy_template(&ing_gen, &DeployConfig::new(hosts, 8, pack), &root)
            .expect("ingest probe: gc template deploy");
        let mut appender =
            CollectionAppender::open(&root, IngestOptions::default().group_commit(8))
                .expect("gc appender");
        for t in 0..n_inst {
            appender.append(&ing_gen.instance(t)).expect("gc append");
        }
        let gc = appender.finish().expect("gc finish");
        let gc_inst_per_s = gc.appended as f64 / gc.append_wall_s.max(1e-9);
        report.row(&[
            "ingest append (group commit 8)".into(),
            format!("{gc_inst_per_s:.1}"),
            format!("inst/s ({} WAL fsyncs vs {})", gc.wal_syncs, ing.wal_syncs),
        ]);
        println!(
            "group commit: {inst_per_s:.1} -> {gc_inst_per_s:.1} inst/s \
             ({} -> {} WAL fsyncs)",
            ing.wal_syncs, gc.wal_syncs
        );
        json.push(("ingest_append_inst_per_s_gc8".into(), gc_inst_per_s));
        json.push(("ingest_wal_syncs_gc8".into(), gc.wal_syncs as f64));

        // Follow-mode lag over a fresh feed.
        let _ = std::fs::remove_dir_all(&root);
        deploy_template(&ing_gen, &DeployConfig::new(hosts, 8, pack), &root)
            .expect("ingest probe: template redeploy");
        let appended: Arc<std::sync::Mutex<Vec<(usize, std::time::Instant)>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let computed: Arc<std::sync::Mutex<std::collections::HashMap<usize, std::time::Instant>>> =
            Arc::new(std::sync::Mutex::new(std::collections::HashMap::new()));
        let feed_root = root.clone();
        let feed_stamps = appended.clone();
        let feed_params = (
            ing_gen.params().n_vertices,
            n_inst,
            ing_gen.params().traces_per_instance,
        );
        let feeder = std::thread::spawn(move || {
            let gen = TraceRouteGenerator::new(TraceRouteParams {
                n_vertices: feed_params.0,
                n_instances: feed_params.1,
                traces_per_instance: feed_params.2,
                ..Default::default()
            });
            std::thread::sleep(std::time::Duration::from_millis(50));
            let mut app = CollectionAppender::open(&feed_root, IngestOptions::default())
                .expect("feeder appender");
            for t in 0..gen.n_instances() {
                app.append(&gen.instance(t)).expect("feeder append");
                feed_stamps.lock().unwrap().push((t, std::time::Instant::now()));
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        });
        struct StampApp {
            computed: Arc<std::sync::Mutex<std::collections::HashMap<usize, std::time::Instant>>>,
        }
        struct StampProgram {
            computed: Arc<std::sync::Mutex<std::collections::HashMap<usize, std::time::Instant>>>,
        }
        impl SubgraphProgram for StampProgram {
            fn compute(
                &mut self,
                ctx: &mut ComputeCtx<'_>,
                _sgi: &goffish::gofs::SubgraphInstance,
                _msgs: &[Payload],
            ) {
                if ctx.superstep == 1 {
                    self.computed
                        .lock()
                        .unwrap()
                        .entry(ctx.timestep)
                        .or_insert_with(std::time::Instant::now);
                }
                ctx.vote_to_halt();
            }
        }
        impl Application for StampApp {
            fn name(&self) -> &str {
                "stamp"
            }
            fn pattern(&self) -> Pattern {
                Pattern::Sequential
            }
            fn projection(&self, vs: &Schema, es: &Schema) -> Projection {
                Projection::all(vs, es) // realistic load per timestep
            }
            fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
                Box::new(StampProgram { computed: self.computed.clone() })
            }
        }
        let (eng, _m) = engine(&root, hosts, 28);
        let stats = eng
            .run(
                &StampApp { computed: computed.clone() },
                &RunOptions {
                    follow: true,
                    follow_poll_ms: 2,
                    follow_idle_polls: 500,
                    ..Default::default()
                },
            )
            .expect("follow run");
        feeder.join().expect("feeder thread");
        let appended = appended.lock().unwrap();
        let computed = computed.lock().unwrap();
        let lags: Vec<f64> = appended
            .iter()
            .filter_map(|&(t, at)| {
                computed.get(&t).map(|&ct| ct.saturating_duration_since(at).as_secs_f64())
            })
            .collect();
        let lag_ms = if lags.is_empty() {
            -1.0
        } else {
            lags.iter().sum::<f64>() / lags.len() as f64 * 1e3
        };
        report.row(&[
            "follow-mode lag".into(),
            format!("{lag_ms:.1}"),
            format!("ms append->compute ({} timesteps live)", stats.per_timestep.len()),
        ]);
        json.push(("ingest_follow_lag_ms".into(), lag_ms));
        assert_eq!(
            stats.per_timestep.len(),
            n_inst,
            "follow run missed appended timesteps"
        );
        let _ = std::fs::remove_dir_all(&root);

        // Satellite: background group compaction. A pack-1 ingest leaves
        // one sealed group per timestep; compacting to groups of 8 must
        // shrink both the group count and the slice reads of a full
        // projection scan, with bit-identical SSSP before and after.
        let _ = std::fs::remove_dir_all(&root);
        deploy_template(&ing_gen, &DeployConfig::new(hosts, 8, 1), &root)
            .expect("compact probe: template deploy");
        let mut appender =
            CollectionAppender::open(&root, IngestOptions::default()).expect("appender");
        for t in 0..n_inst {
            appender.append(&ing_gen.instance(t)).expect("append");
        }
        drop(appender);
        let scan_reads = |root: &PathBuf| -> (u64, usize) {
            let (eng, _m) = engine(root, hosts, 256);
            let mut reads = 0u64;
            let mut groups = 0usize;
            for s in eng.stores() {
                groups += s.sealed_groups();
                let proj = Projection::all(s.vertex_schema(), s.edge_schema());
                for t in 0..s.n_instances() {
                    for sg in s.subgraphs() {
                        let mut tr = ReadTrace::default();
                        s.read_instance_traced(sg.id.local(), t, &proj, &mut tr)
                            .expect("scan read");
                        reads += tr.slices_read;
                    }
                }
            }
            (reads, groups)
        };
        let source = ing_gen.template().ext_ids[ing_gen.vantages()[0] as usize];
        let (reads_before, groups_before) = scan_reads(&root);
        let (_, fp_before) = sssp_fingerprint(&root, hosts, source, n_inst, true, 4, true);
        let c0 = std::time::Instant::now();
        let creport = compact_collection(&root, &CompactOptions::new(8))
            .expect("compact probe: compaction");
        let compact_s = c0.elapsed().as_secs_f64();
        let (reads_after, groups_after) = scan_reads(&root);
        let (_, fp_after) = sssp_fingerprint(&root, hosts, source, n_inst, true, 4, true);
        assert_eq!(fp_before, fp_after, "compaction changed SSSP outputs");
        assert!(
            groups_after < groups_before && reads_after < reads_before,
            "compaction must amortize: groups {groups_before}->{groups_after}, \
             reads {reads_before}->{reads_after}"
        );
        let amp = reads_before as f64 / reads_after.max(1) as f64;
        report.row(&[
            "compaction".into(),
            format!("{amp:.2}x"),
            format!(
                "fewer slice reads/scan ({groups_before}->{groups_after} groups, \
                 {} merged)",
                creport.groups_merged
            ),
        ]);
        json.push(("compact_groups_before".into(), groups_before as f64));
        json.push(("compact_groups_after".into(), groups_after as f64));
        json.push(("compact_scan_slices_before".into(), reads_before as f64));
        json.push(("compact_scan_slices_after".into(), reads_after as f64));
        json.push(("compact_read_amplification_x".into(), amp));
        json.push((
            "compact_ms_per_source_group".into(),
            compact_s * 1e3 / creport.groups_merged.max(1) as f64,
        ));
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- Cluster runtime: heartbeat overhead + crash-rejoin recovery. ---
    // Three identical 2-host TCP runs over a tiny collection: heartbeats
    // off, heartbeats on (the fault-free liveness tax), and heartbeats
    // on with an injected connection drop mid-run (teardown + rejoin +
    // checkpoint resume). All three must produce identical output; the
    // deltas are the costs.
    {
        use goffish::cluster::coordinator::{run_coordinator, CoordinatorConfig};
        use goffish::cluster::worker::{run_host, HostConfig};
        use goffish::gofs::{DiskModel, StoreOptions};

        let cgen = TraceRouteGenerator::new(TraceRouteParams::tiny());
        let root =
            std::env::temp_dir().join(format!("goffish-bench-cluster-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        deploy(&cgen, &DeployConfig::new(2, 4, 3), &root).expect("deploy cluster probe");
        let csource = cgen.template().ext_ids[cgen.vantages()[0] as usize];

        let run_cluster = |tag: &str, heartbeat_ms: u64, plan: Option<PathBuf>| -> (f64, String) {
            let port_file = root.join(format!("port-{tag}"));
            let _ = std::fs::remove_file(&port_file);
            let cfg = CoordinatorConfig {
                n_hosts: 2,
                listen: "127.0.0.1:0".into(),
                port_file: Some(port_file.clone()),
                app_name: "sssp".into(),
                app_params: vec![("source".into(), csource.to_string())],
                heartbeat_ms,
                ..Default::default()
            };
            let t0 = std::time::Instant::now();
            let coord = std::thread::spawn(move || run_coordinator(&cfg));
            let port: u16 = loop {
                if let Ok(s) = std::fs::read_to_string(&port_file) {
                    if let Ok(p) = s.trim().parse() {
                        break p;
                    }
                }
                assert!(
                    t0.elapsed() < std::time::Duration::from_secs(30),
                    "cluster probe coordinator never published its port"
                );
                std::thread::sleep(std::time::Duration::from_millis(2));
            };
            let hosts: Vec<_> = (0..2usize)
                .map(|part| {
                    let cfg = HostConfig {
                        root: root.clone(),
                        part,
                        coordinator: format!("127.0.0.1:{port}"),
                        store_opts: StoreOptions {
                            cache_slots: 16,
                            disk: DiskModel::instant(),
                            ..Default::default()
                        },
                        heartbeat_ms,
                        retry_base_ms: 10,
                        fault_plan: if part == 1 { plan.clone() } else { None },
                        ..Default::default()
                    };
                    std::thread::spawn(move || run_host(&cfg))
                })
                .collect();
            for h in hosts {
                h.join().unwrap().expect("cluster probe host");
            }
            let out = coord.join().unwrap().expect("cluster probe coordinator");
            (t0.elapsed().as_secs_f64(), out)
        };

        let (wall_off, out_off) = run_cluster("hb-off", 0, None);
        let (wall_on, out_on) = run_cluster("hb-on", 25, None);
        assert_eq!(out_on, out_off, "heartbeats changed the run output");
        let plan = root.join("faults.plan");
        std::fs::write(&plan, "on host1.send.Superstep nth 4 drop\n").unwrap();
        let (wall_chaos, out_chaos) = run_cluster("rejoin", 25, Some(plan));
        assert_eq!(out_chaos, out_off, "crash-rejoin changed the run output");
        let heartbeat_overhead_ms = (wall_on - wall_off) * 1e3;
        let rejoin_recovery_ms = (wall_chaos - wall_on) * 1e3;
        report.row(&[
            "heartbeat overhead (2-host run, 25ms beat)".into(),
            format!("{heartbeat_overhead_ms:.1}"),
            "ms added to fault-free wall".into(),
        ]);
        report.row(&[
            "rejoin recovery (drop -> teardown -> resume)".into(),
            format!("{rejoin_recovery_ms:.1}"),
            "ms added to run wall".into(),
        ]);
        json.push(("heartbeat_overhead_ms".into(), heartbeat_overhead_ms));
        json.push(("rejoin_recovery_ms".into(), rejoin_recovery_ms));
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- Observability plane: journal + metric shipping overhead. ---
    // The same 2-host TCP run with the full plane on (per-host event
    // journals, snapshot piggybacking on Heartbeat/Commit frames,
    // coordinator journal + RUN_METRICS.json dump) vs everything off.
    // Outputs must be byte-identical; the wall delta is the whole-run
    // observability tax. Plus a micro-probe for the journal append
    // itself (CRC-framed JSONL line, buffered write, no fsync).
    {
        use goffish::cluster::coordinator::{run_coordinator, CoordinatorConfig};
        use goffish::cluster::worker::{run_host, HostConfig};
        use goffish::gofs::{DiskModel, StoreOptions};
        use goffish::metrics::journal::Journal;

        let cgen = TraceRouteGenerator::new(TraceRouteParams::tiny());
        let root =
            std::env::temp_dir().join(format!("goffish-bench-obs-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        deploy(&cgen, &DeployConfig::new(2, 4, 3), &root).expect("deploy obs probe");
        let csource = cgen.template().ext_ids[cgen.vantages()[0] as usize];

        let run_obs = |tag: &str, observe: bool| -> (f64, String) {
            let port_file = root.join(format!("port-{tag}"));
            let _ = std::fs::remove_file(&port_file);
            let cfg = CoordinatorConfig {
                n_hosts: 2,
                listen: "127.0.0.1:0".into(),
                port_file: Some(port_file.clone()),
                app_name: "sssp".into(),
                app_params: vec![("source".into(), csource.to_string())],
                heartbeat_ms: 25,
                metrics_out: observe.then(|| root.join(format!("RUN_METRICS-{tag}.json"))),
                journal: observe.then(|| root.join(format!("coord-{tag}.journal"))),
                ..Default::default()
            };
            let t0 = std::time::Instant::now();
            let coord = std::thread::spawn(move || run_coordinator(&cfg));
            let port: u16 = loop {
                if let Ok(s) = std::fs::read_to_string(&port_file) {
                    if let Ok(p) = s.trim().parse() {
                        break p;
                    }
                }
                assert!(
                    t0.elapsed() < std::time::Duration::from_secs(30),
                    "obs probe coordinator never published its port"
                );
                std::thread::sleep(std::time::Duration::from_millis(2));
            };
            let hosts: Vec<_> = (0..2usize)
                .map(|part| {
                    let cfg = HostConfig {
                        root: root.clone(),
                        part,
                        coordinator: format!("127.0.0.1:{port}"),
                        store_opts: StoreOptions {
                            cache_slots: 16,
                            disk: DiskModel::instant(),
                            ..Default::default()
                        },
                        heartbeat_ms: 25,
                        retry_base_ms: 10,
                        journal: observe
                            .then(|| root.join(format!("host{part}-{tag}.journal"))),
                        ship_metrics: observe,
                        ..Default::default()
                    };
                    std::thread::spawn(move || run_host(&cfg))
                })
                .collect();
            for h in hosts {
                h.join().unwrap().expect("obs probe host");
            }
            let out = coord.join().unwrap().expect("obs probe coordinator");
            (t0.elapsed().as_secs_f64(), out)
        };

        let _ = run_obs("warm", false); // page in the binary + collection
        let (wall_off, out_off) = run_obs("plane-off", false);
        let (wall_on, out_on) = run_obs("plane-on", true);
        assert_eq!(out_on, out_off, "observability plane changed the run output");
        assert!(
            root.join("RUN_METRICS-plane-on.json").exists(),
            "observed run wrote no RUN_METRICS.json"
        );
        let metrics_overhead_ms = (wall_on - wall_off) * 1e3;
        report.row(&[
            "observability plane (journal + shipping + dump)".into(),
            format!("{metrics_overhead_ms:.1}"),
            "ms added to 2-host run wall".into(),
        ]);
        json.push(("metrics_overhead_ms".into(), metrics_overhead_ms));

        let jpath = root.join("micro.journal");
        let j = Journal::open(&jpath, "bench").expect("open micro journal");
        let mut t = 0u64;
        let jstats = b.bench("journal append", || {
            t += 1;
            j.event("probe", &[("t", t.into()), ("tag", "bench".into())]);
        });
        report.row(&[
            "journal append".into(),
            format!("{:.2}", jstats.min() * 1e6),
            "us/event (CRC-framed JSONL)".into(),
        ]);
        json.push(("journal_append_us".into(), jstats.min() * 1e6));
        println!(
            "observability probe: {metrics_overhead_ms:.1} ms plane overhead, \
             {:.2} us/journal event (outputs identical)",
            jstats.min() * 1e6
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- Storage integrity: scrub throughput + read-repair latency. ---
    // Offline scrub over the main cached deployment (container CRC +
    // full body decode of every referenced slice, WAL tail, metadata
    // invariants), normalized per GB verified. Then the read path's
    // self-heal: every part-0 attribute slice of a small replicated
    // deployment is bit-flipped at rest, and a full-projection scan
    // detects, restores from the replica (durable replace) and re-reads
    // each one — per-repair latency from the `gofs.read_repair_ms`
    // histogram those heals record.
    {
        use goffish::gofs::{open_collection, scrub, DiskModel, ScrubOptions, StoreOptions};
        use goffish::metrics::hkeys;

        let (srep, wall) =
            Bencher::once(|| scrub(&dir, &ScrubOptions::default()).expect("scrub probe"));
        assert!(srep.clean(), "bench deployment failed its scrub: {:?}", srep.corrupt);
        let gb = srep.bytes_checked as f64 / 1e9;
        let scrub_ms_per_gb = wall * 1e3 / gb.max(1e-9);
        report.row(&[
            "scrub".into(),
            format!("{scrub_ms_per_gb:.0}"),
            format!("ms/GB ({} slices, {:.2} GB verified)", srep.slices_checked, gb),
        ]);
        json.push(("scrub_ms_per_gb".into(), scrub_ms_per_gb));

        let rr_gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
        let root =
            std::env::temp_dir().join(format!("goffish-bench-repair-{}", std::process::id()));
        let replica = root.join("replica"); // outside the collection parts
        let primary = root.join("primary");
        let _ = std::fs::remove_dir_all(&root);
        deploy(&rr_gen, &DeployConfig::new(2, 4, 3), &primary).expect("repair probe: deploy");
        // Replica := byte-copy of the clean store; then rot the primary.
        let mut stack = vec![primary.clone()];
        while let Some(d) = stack.pop() {
            for e in std::fs::read_dir(&d).unwrap() {
                let p = e.unwrap().path();
                if p.is_dir() {
                    stack.push(p);
                } else {
                    let dst = replica.join(p.strip_prefix(&primary).unwrap());
                    std::fs::create_dir_all(dst.parent().unwrap()).unwrap();
                    std::fs::copy(&p, &dst).unwrap();
                }
            }
        }
        let mut rotted = 0usize;
        let mut stack = vec![primary.join("part-0/attr")];
        while let Some(d) = stack.pop() {
            for e in std::fs::read_dir(&d).unwrap() {
                let p = e.unwrap().path();
                if p.is_dir() {
                    stack.push(p);
                } else {
                    let mut raw = std::fs::read(&p).unwrap();
                    raw[16] ^= 0x01; // past the header: body CRC/inflate catches it
                    std::fs::write(&p, raw).unwrap();
                    rotted += 1;
                }
            }
        }
        let metrics = Arc::new(Metrics::new());
        let opts = StoreOptions {
            cache_slots: 64,
            disk: DiskModel::instant(),
            metrics: metrics.clone(),
            replica_dir: Some(replica.clone()),
            ..Default::default()
        };
        let stores = open_collection(&primary, &opts).expect("repair probe: open");
        for s in &stores {
            let proj = Projection::all(s.vertex_schema(), s.edge_schema());
            for t in 0..s.n_instances() {
                for sg in s.subgraphs() {
                    s.read_instance(sg.id.local(), t, &proj).expect("repair probe: healed read");
                }
            }
        }
        let h = metrics.hist(hkeys::READ_REPAIR_MS).expect("scan repaired nothing");
        let healed = h.total() as usize;
        assert!(
            healed >= 1 && healed <= rotted,
            "healed {healed} of {rotted} rotted slices (each heals at most once)"
        );
        let read_repair_ms = h.quantile(0.5).unwrap_or(-1.0);
        report.row(&[
            "read repair".into(),
            format!("{read_repair_ms:.2}"),
            format!("ms p50 detect -> durable restore ({healed}/{rotted} slices healed)"),
        ]);
        json.push(("read_repair_ms".into(), read_repair_ms));
        println!(
            "storage probe: scrub {scrub_ms_per_gb:.0} ms/GB, read repair \
             {read_repair_ms:.2} ms p50 ({healed} slices healed in place)"
        );
        // The post-heal scrub must agree the primary is clean again.
        let srep = scrub(&primary, &ScrubOptions::default()).expect("post-heal scrub");
        assert!(srep.clean(), "read repair left corruption behind: {:?}", srep.corrupt);
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- Partitioning quality: streaming fennel vs count-only binpack. ---
    // Planted-cluster graph (dense intra-cluster ring+chords, one weak
    // edge between consecutive clusters): a graph-aware streamer should
    // keep clusters whole while the count-only baseline shreds them.
    // Both deployments run WCC on a 2-host in-process engine; the probe
    // asserts identical component outputs and reports the template edge
    // cut plus routed bytes per superstep under each partitioner.
    {
        use goffish::apps::WccApp;
        use goffish::graph::{
            GraphInstance, GraphTemplate, TemplateBuilder, TimeWindow, Timestep,
        };
        use goffish::partition::PartitionStrategy;

        struct ClusterSource {
            template: GraphTemplate,
        }
        impl CollectionSource for ClusterSource {
            fn template(&self) -> &GraphTemplate {
                &self.template
            }
            fn n_instances(&self) -> usize {
                1
            }
            fn instance(&self, t: Timestep) -> GraphInstance {
                GraphInstance::empty(
                    &self.template,
                    t,
                    TimeWindow::new(t as i64 * 10, t as i64 * 10 + 10),
                )
            }
        }

        let (clusters, csize) = (8usize, 48usize);
        let n = clusters * csize;
        let mut tb = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
        for i in 0..n {
            tb.vertex(i as u64);
        }
        for c in 0..clusters {
            let base = (c * csize) as u32;
            for i in 0..csize as u32 {
                tb.edge(base + i, base + (i + 1) % csize as u32);
                tb.edge(base + i, base + (i + 7) % csize as u32);
            }
            // One weak edge to the next cluster closes a ring of clusters.
            tb.edge(base, (base + csize as u32) % n as u32);
        }
        let src = ClusterSource { template: tb.build() };

        // Deploy + WCC under one strategy; canonical output is the sorted
        // (ext id, component label) relation — labels are component
        // min-ext-ids, so the relation is partition-invariant.
        let probe = |strategy: PartitionStrategy| -> (f64, f64, Vec<(u64, u64)>) {
            let root = std::env::temp_dir().join(format!(
                "goffish-bench-part-{}-{}",
                strategy.name(),
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&root);
            let mut cfg = DeployConfig::new(2, 4, 1);
            cfg.partition.strategy = strategy;
            let rep = deploy(&src, &cfg, &root).expect("partition probe: deploy");
            let (eng, _m) = engine(&root, 2, 16);
            let app = WccApp::new();
            let stats = eng
                .run(&app, &RunOptions { timesteps: Some(vec![0]), ..Default::default() })
                .expect("partition probe: wcc");
            let labels = app.results.labels.lock().unwrap();
            let mut canon: Vec<(u64, u64)> = Vec::new();
            for s in eng.stores() {
                for sg in s.subgraphs() {
                    let label = labels[&sg.id];
                    for &ext in &sg.ext_ids {
                        canon.push((ext, label));
                    }
                }
            }
            canon.sort_unstable();
            drop(labels);
            let per_ss =
                stats.total_routed_bytes() as f64 / stats.total_supersteps().max(1) as f64;
            let _ = std::fs::remove_dir_all(&root);
            (rep.edge_cut_pct, per_ss, canon)
        };

        let (cut_bp, bytes_bp, canon_bp) = probe(PartitionStrategy::Binpack);
        let (cut_fn, bytes_fn, canon_fn) = probe(PartitionStrategy::Fennel);
        assert_eq!(
            canon_bp, canon_fn,
            "partitioner changed WCC component outputs"
        );
        assert!(
            cut_fn < cut_bp,
            "fennel edge cut {cut_fn:.2}% not below binpack {cut_bp:.2}%"
        );
        assert!(
            bytes_fn < bytes_bp,
            "fennel routed {bytes_fn:.0} B/superstep not below binpack {bytes_bp:.0}"
        );
        report.row(&[
            "edge cut (planted clusters, k=2)".into(),
            format!("{cut_fn:.2}% vs {cut_bp:.2}%"),
            "fennel vs binpack (identical WCC outputs)".into(),
        ]);
        report.row(&[
            "routed bytes/superstep".into(),
            format!("{bytes_fn:.0} vs {bytes_bp:.0}"),
            "fennel vs binpack, WCC on 2 hosts".into(),
        ]);
        json.push(("edge_cut_pct_binpack".into(), cut_bp));
        json.push(("edge_cut_pct_fennel".into(), cut_fn));
        json.push(("routed_bytes_per_superstep_binpack".into(), bytes_bp));
        json.push(("routed_bytes_per_superstep_fennel".into(), bytes_fn));
        println!(
            "partition probe: edge cut {cut_fn:.2}% (fennel) vs {cut_bp:.2}% (binpack), \
             routed {bytes_fn:.0} vs {bytes_bp:.0} B/superstep, outputs identical"
        );
    }

    // --- L1/L2: kernel dispatch + throughput vs scalar. ---
    match PjrtEngine::load(
        &std::path::PathBuf::from(
            std::env::var("GOFFISH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        ),
        None,
        Arc::new(Metrics::new()),
    ) {
        Ok(pjrt) => {
            let kb = pjrt.b;
            let kk = pjrt.k;
            let kernel = format!("pagerank_b{kb}_k{kk}");
            let a = vec![0.5f32; kk * kb * kb];
            let x = vec![1.0f32; kk * kb];
            let stats = b.bench("pjrt kernel call", || {
                pjrt.execute(&kernel, vec![(a.clone(), vec![kk, kb, kb]), (x.clone(), vec![kk, kb])])
                    .unwrap()
            });
            let flops = 2.0 * (kk * kb * kb) as f64;
            report.row(&[
                format!("pjrt kernel b={kb} k={kk}"),
                format!("{:.2}", flops / stats.min() / 1e9),
                "GFLOP/s (dispatch incl.)".into(),
            ]);
            json.push(("pjrt_gflops".into(), flops / stats.min() / 1e9));

            // End-to-end prepared-op apply: pjrt vs scalar on a dense-ish subgraph.
            for n in [512usize, 2048] {
                let sg = dense_subgraph(n, 8);
                let active = vec![true; sg.n_local_edges()];
                let backend = PjrtBackend::new(pjrt.clone());
                let op_p = LocalSpmv::prepare(&backend, &sg, &active);
                let op_s = LocalSpmv::prepare(&ScalarBackend, &sg, &active);
                let xs: Vec<f32> = (0..n).map(|i| i as f32 / n as f32).collect();
                let mut y = vec![0.0f32; n];
                let sp = b.bench(&format!("pjrt spmv n={n}"), || op_p.apply(&xs, &mut y));
                let ss = b.bench(&format!("scalar spmv n={n}"), || op_s.apply(&xs, &mut y));
                report.row(&[
                    format!("spmv n={n} ({} edges)", sg.n_local_edges()),
                    format!("{:.2}x", ss.min() / sp.min()),
                    "pjrt speedup over scalar (>1 = faster)".into(),
                ]);
            }
        }
        Err(e) => println!("pjrt probes skipped: {e}"),
    }

    report.print("P1 — hot-path probes");

    // --- Machine-readable series for CI (BENCH_hotpath.json). ---
    let json_path = PathBuf::from(
        args.get("json").unwrap_or("BENCH_hotpath.json").to_string(),
    );
    let mut out = String::from("{\n");
    for (i, (k, v)) in json.iter().enumerate() {
        let sep = if i + 1 == json.len() { "" } else { "," };
        let v = if v.is_finite() { *v } else { -1.0 };
        out.push_str(&format!("  \"{k}\": {v:.3}{sep}\n"));
    }
    out.push_str("}\n");
    std::fs::write(&json_path, &out).expect("write BENCH_hotpath.json");
    println!("wrote {}", json_path.display());
}

/// A single-subgraph graph with average degree `deg` (for kernel benches).
fn dense_subgraph(n: usize, deg: usize) -> Subgraph {
    use goffish::graph::TemplateBuilder;
    use goffish::partition::{extract_partitions, Partitioning};
    let mut rng = Prng::new(99);
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    for i in 0..n {
        b.vertex(i as u64);
    }
    for i in 0..n - 1 {
        b.edge(i as u32, i as u32 + 1);
    }
    for _ in 0..n * (deg - 1) {
        let s = rng.gen_range(n as u64) as u32;
        let d = rng.gen_range(n as u64) as u32;
        b.edge(s, d);
    }
    let t = b.build();
    let p = Partitioning { n_parts: 1, assign: vec![0; n] };
    extract_partitions(&t, &p).remove(0).subgraphs.remove(0)
}

/// Time a one-superstep all-to-neighbors broadcast; msgs/sec routed.
fn bench_message_routing(eng: &GopherEngine, b: &Bencher) -> f64 {
    struct Blast;
    struct BlastProgram;
    impl SubgraphProgram for BlastProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, sgi: &goffish::gofs::SubgraphInstance, msgs: &[Payload]) {
            if ctx.superstep == 1 {
                for r in sgi.sg.remote.iter().take(64) {
                    ctx.send_to_subgraph(r.dst_subgraph, vec![0u8; 16]);
                }
            }
            let _ = msgs;
            ctx.vote_to_halt();
        }
    }
    impl Application for Blast {
        fn name(&self) -> &str {
            "blast"
        }
        fn pattern(&self) -> Pattern {
            Pattern::Sequential
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(BlastProgram)
        }
    }
    let stats = b.bench("message blast", || {
        eng.run(&Blast, &RunOptions { timesteps: Some(vec![0]), ..Default::default() }).unwrap()
    });
    let msgs: u64 = {
        let s = eng
            .run(&Blast, &RunOptions { timesteps: Some(vec![0]), ..Default::default() })
            .unwrap();
        s.per_timestep[0].msgs_local + s.per_timestep[0].msgs_remote
    };
    msgs as f64 / stats.min()
}
