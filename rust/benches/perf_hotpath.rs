//! P1 — hot-path microbenchmarks for the §Perf optimization loop.
//!
//! Measures each layer's critical operation in isolation so before/after
//! deltas in EXPERIMENTS.md §Perf are attributable:
//!   L3: slice decode, cache hit path, superstep barrier overhead,
//!       message routing;
//!   L1/L2 via PJRT: kernel dispatch latency + tile throughput vs the
//!       scalar backend at several subgraph sizes.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::apps::SsspApp;
use goffish::datagen::{traceroute, CollectionSource};
use goffish::gofs::{Projection, SliceFile};
use goffish::graph::Schema;
use goffish::gopher::{
    Application, ComputeCtx, GopherEngine, Pattern, Payload, RunOptions, RunStats,
    SubgraphProgram,
};
use goffish::metrics::Metrics;
use goffish::partition::Subgraph;
use goffish::runtime::pjrt::{PjrtBackend, PjrtEngine};
use goffish::runtime::{LocalSpmv, ScalarBackend};
use goffish::util::bench::{BenchArgs, Bencher, Table};
use goffish::util::Prng;
use std::sync::Arc;

/// No-op app used to time pure engine overhead.
struct NoopApp {
    supersteps: usize,
}
struct NoopProgram {
    supersteps: usize,
}
impl SubgraphProgram for NoopProgram {
    fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &goffish::gofs::SubgraphInstance, _msgs: &[Payload]) {
        if ctx.superstep >= self.supersteps {
            ctx.vote_to_halt();
        }
    }
}
impl Application for NoopApp {
    fn name(&self) -> &str {
        "noop"
    }
    fn pattern(&self) -> Pattern {
        Pattern::Sequential
    }
    fn projection(&self, _: &Schema, _: &Schema) -> Projection {
        Projection::none()
    }
    fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
        Box::new(NoopProgram { supersteps: self.supersteps })
    }
}

fn main() {
    let args = BenchArgs::from_env();
    let scale = BenchScale::from_args(&args);
    let gen = scale.generator();
    let (dir, _) = deploy_cached(&gen, &scale, 20, 20);
    let b = Bencher::new(1, args.usize("iters", 5));
    let mut report = Table::new(&["probe", "value", "unit"]);

    // --- L3: slice decode throughput. ---
    let sample = {
        // find a reasonably sized attribute slice
        let mut best: Option<(std::path::PathBuf, u64)> = None;
        let mut stack = vec![dir.join("part-0/attr")];
        while let Some(d) = stack.pop() {
            for e in std::fs::read_dir(&d).unwrap() {
                let e = e.unwrap();
                if e.path().is_dir() {
                    stack.push(e.path());
                } else {
                    let len = e.metadata().unwrap().len();
                    if best.as_ref().map(|(_, l)| len > *l).unwrap_or(true) {
                        best = Some((e.path(), len));
                    }
                }
            }
        }
        best.unwrap()
    };
    let bytes = std::fs::read(&sample.0).unwrap();
    let stats = b.bench("slice decode", || SliceFile::from_bytes(&bytes).unwrap());
    report.row(&[
        "slice decode".into(),
        format!("{:.1}", sample.1 as f64 / stats.min() / 1e6),
        "MB/s (on-disk bytes)".into(),
    ]);

    // --- L3: cache hit path. ---
    let stores = open_stores(&dir, 1, 64, Arc::new(Metrics::new()));
    let store = &stores[0];
    let proj = Projection::all(store.vertex_schema(), store.edge_schema());
    let sg0 = store.subgraphs()[0].id.local();
    let _ = store.read_instance(sg0, 0, &proj).unwrap(); // warm
    let stats = b.bench("cached read_instance", || store.read_instance(sg0, 0, &proj).unwrap());
    report.row(&[
        "cached read_instance".into(),
        format!("{:.1}", stats.min() * 1e6),
        "us".into(),
    ]);

    // --- L3: superstep barrier overhead (noop app, many supersteps). ---
    let (eng, _m) = engine(&dir, scale.hosts, 28);
    let supersteps = 50usize;
    let stats = b.bench("noop supersteps", || {
        eng.run(
            &NoopApp { supersteps },
            &RunOptions { timesteps: Some(vec![0]), ..Default::default() },
        )
        .unwrap()
    });
    let n_sg = eng.n_subgraphs();
    report.row(&[
        "superstep barrier+dispatch".into(),
        format!("{:.1}", stats.min() / supersteps as f64 * 1e6),
        format!("us/superstep ({n_sg} subgraphs)"),
    ]);

    // --- L3: message routing throughput. ---
    let routing = bench_message_routing(&eng, &b);
    report.row(&[
        "message routing".into(),
        format!("{:.2}", routing / 1e6),
        "M msgs/s".into(),
    ]);

    // --- L3: pipelined instance loading (prefetch + parallel load). ---
    // Per-timestep *blocking* load wall time for the temporal SSSP app,
    // with the pipeline off (serial load on the driver thread, no
    // prefetch — the pre-pipelining engine) vs. on (default). App outputs
    // must be bit-identical; the acceptance bar is >= 1.5x.
    {
        let n_ts = args.usize("timesteps", 8).min(scale.instances);
        let source = gen.template().ext_ids[gen.vantages()[0] as usize];
        let run_sssp = |prefetch: bool, workers: usize| -> (RunStats, Vec<(u64, usize, i64)>) {
            let (eng, _m) = engine(&dir, scale.hosts, 28);
            let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
            let stats = eng
                .run(
                    &app,
                    &RunOptions {
                        timesteps: Some((0..n_ts).collect()),
                        prefetch,
                        workers,
                        ..Default::default()
                    },
                )
                .expect("sssp run");
            // Output fingerprint: quantized final distance per vertex.
            let distances = app.results.distances.lock().unwrap();
            let mut fp: Vec<(u64, usize, i64)> = distances
                .iter()
                .flat_map(|(sgid, (t, d))| {
                    d.iter().enumerate().map(move |(lv, &x)| {
                        let q = if x.is_finite() { (x as f64 * 1e4).round() as i64 } else { -1 };
                        (sgid.0, *t * 1_000_000 + lv, q)
                    })
                })
                .collect();
            fp.sort_unstable();
            (stats, fp)
        };
        let (off, fp_off) = run_sssp(false, 1);
        let (on, fp_on) = run_sssp(true, RunOptions::default().workers);
        assert_eq!(fp_off, fp_on, "prefetch/parallel load changed SSSP outputs");
        let block_off = off.total_load_blocking_s() / n_ts as f64;
        let block_on = on.total_load_blocking_s() / n_ts as f64;
        let overlap_on: f64 =
            on.per_timestep.iter().map(|t| t.overlap_s).sum::<f64>() / n_ts as f64;
        report.row(&[
            "load blocking (pipeline OFF)".into(),
            format!("{:.2}", block_off * 1e3),
            "ms/timestep (serial load, no prefetch)".into(),
        ]);
        report.row(&[
            "load blocking (pipeline ON)".into(),
            format!("{:.2}", block_on * 1e3),
            format!("ms/timestep (overlap {:.2} ms hidden)", overlap_on * 1e3),
        ]);
        let speedup = block_off / block_on.max(1e-9);
        report.row(&[
            "load pipeline speedup".into(),
            format!("{speedup:.2}x"),
            "blocking load, OFF/ON (>= 1.5x expected)".into(),
        ]);
        println!(
            "load pipeline: {:.2} -> {:.2} ms blocking load/timestep ({speedup:.2}x, outputs identical)",
            block_off * 1e3,
            block_on * 1e3
        );
    }

    // --- L1/L2: kernel dispatch + throughput vs scalar. ---
    match PjrtEngine::load(
        &std::path::PathBuf::from(
            std::env::var("GOFFISH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        ),
        None,
        Arc::new(Metrics::new()),
    ) {
        Ok(pjrt) => {
            let kb = pjrt.b;
            let kk = pjrt.k;
            let kernel = format!("pagerank_b{kb}_k{kk}");
            let a = vec![0.5f32; kk * kb * kb];
            let x = vec![1.0f32; kk * kb];
            let stats = b.bench("pjrt kernel call", || {
                pjrt.execute(&kernel, vec![(a.clone(), vec![kk, kb, kb]), (x.clone(), vec![kk, kb])])
                    .unwrap()
            });
            let flops = 2.0 * (kk * kb * kb) as f64;
            report.row(&[
                format!("pjrt kernel b={kb} k={kk}"),
                format!("{:.2}", flops / stats.min() / 1e9),
                "GFLOP/s (dispatch incl.)".into(),
            ]);

            // End-to-end prepared-op apply: pjrt vs scalar on a dense-ish subgraph.
            for n in [512usize, 2048] {
                let sg = dense_subgraph(n, 8);
                let active = vec![true; sg.n_local_edges()];
                let backend = PjrtBackend::new(pjrt.clone());
                let op_p = LocalSpmv::prepare(&backend, &sg, &active);
                let op_s = LocalSpmv::prepare(&ScalarBackend, &sg, &active);
                let xs: Vec<f32> = (0..n).map(|i| i as f32 / n as f32).collect();
                let mut y = vec![0.0f32; n];
                let sp = b.bench(&format!("pjrt spmv n={n}"), || op_p.apply(&xs, &mut y));
                let ss = b.bench(&format!("scalar spmv n={n}"), || op_s.apply(&xs, &mut y));
                report.row(&[
                    format!("spmv n={n} ({} edges)", sg.n_local_edges()),
                    format!("{:.2}x", ss.min() / sp.min()),
                    "pjrt speedup over scalar (>1 = faster)".into(),
                ]);
            }
        }
        Err(e) => println!("pjrt probes skipped: {e}"),
    }

    report.print("P1 — hot-path probes");
}

/// A single-subgraph graph with average degree `deg` (for kernel benches).
fn dense_subgraph(n: usize, deg: usize) -> Subgraph {
    use goffish::graph::TemplateBuilder;
    use goffish::partition::{extract_partitions, Partitioning};
    let mut rng = Prng::new(99);
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    for i in 0..n {
        b.vertex(i as u64);
    }
    for i in 0..n - 1 {
        b.edge(i as u32, i as u32 + 1);
    }
    for _ in 0..n * (deg - 1) {
        let s = rng.gen_range(n as u64) as u32;
        let d = rng.gen_range(n as u64) as u32;
        b.edge(s, d);
    }
    let t = b.build();
    let p = Partitioning { n_parts: 1, assign: vec![0; n] };
    extract_partitions(&t, &p).remove(0).subgraphs.remove(0)
}

/// Time a one-superstep all-to-neighbors broadcast; msgs/sec routed.
fn bench_message_routing(eng: &GopherEngine, b: &Bencher) -> f64 {
    struct Blast;
    struct BlastProgram;
    impl SubgraphProgram for BlastProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, sgi: &goffish::gofs::SubgraphInstance, msgs: &[Payload]) {
            if ctx.superstep == 1 {
                for r in sgi.sg.remote.iter().take(64) {
                    ctx.send_to_subgraph(r.dst_subgraph, vec![0u8; 16]);
                }
            }
            let _ = msgs;
            ctx.vote_to_halt();
        }
    }
    impl Application for Blast {
        fn name(&self) -> &str {
            "blast"
        }
        fn pattern(&self) -> Pattern {
            Pattern::Sequential
        }
        fn projection(&self, _: &Schema, _: &Schema) -> Projection {
            Projection::none()
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(BlastProgram)
        }
    }
    let stats = b.bench("message blast", || {
        eng.run(&Blast, &RunOptions { timesteps: Some(vec![0]), ..Default::default() }).unwrap()
    });
    let msgs: u64 = {
        let s = eng
            .run(&Blast, &RunOptions { timesteps: Some(vec![0]), ..Default::default() })
            .unwrap();
        s.per_timestep[0].msgs_local + s.per_timestep[0].msgs_remote
    };
    msgs as f64 / stats.min()
}
