//! Ablation A2 — subgraph bin count sweep (§V-D).
//!
//! "As the bin size increases and tends towards the number of sub-graphs
//! in the partition, this degenerates to the non-bin-packing approach"
//! — many tiny slices, seek-latency bound. Too few bins inflate slice
//! size variance instead. Reports slice count/size stats and scan cost.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::gofs::Projection;
use goffish::metrics::Metrics;
use goffish::util::bench::{BenchArgs, Table};
use goffish::util::stats::Stats;
use std::sync::Arc;

fn main() {
    let args = BenchArgs::from_env();
    let scale = BenchScale::from_args(&args);
    let gen = scale.generator();
    let bins_sweep = [1usize, 5, 10, 20, 40, 80, 160];

    let mut t = Table::new(&[
        "bins", "slices", "bytes (MB)", "slice size p50 (KB)", "slice size max (KB)",
        "scan sim disk (s)", "bin imbalance",
    ]);
    for &bins in &bins_sweep {
        let (dir, report) = deploy_cached(&gen, &scale, bins, 20);
        // Slice size distribution straight from the filesystem.
        let mut sizes = Stats::new();
        for p in 0..scale.hosts {
            let attr_dir = dir.join(format!("part-{p}/attr"));
            if let Ok(walk) = walk_files(&attr_dir) {
                for f in walk {
                    sizes.push(f as f64 / 1024.0);
                }
            }
        }
        let stores = open_stores(&dir, scale.hosts, 14, Arc::new(Metrics::new()));
        for store in &stores {
            let proj = Projection::all(store.vertex_schema(), store.edge_schema());
            for sg in store.subgraphs() {
                for ts in 0..scale.instances {
                    let _ = store.read_instance(sg.id.local(), ts, &proj).unwrap();
                }
            }
        }
        let sim: u64 = stores.iter().map(|s| s.sim_disk_ns()).sum();
        let imbalance = stores
            .iter()
            .map(|s| s.shared().bins.imbalance())
            .fold(0.0f64, f64::max);
        t.row(&[
            bins.to_string(),
            report.slices_written.to_string(),
            format!("{:.1}", report.bytes_written as f64 / 1e6),
            format!("{:.1}", sizes.median()),
            format!("{:.1}", sizes.max()),
            format!("{:.2}", sim as f64 / 1e9),
            format!("{imbalance:.2}"),
        ]);
    }
    t.print("A2 — bin count sweep (i20, c14, full scan)");
}

fn walk_files(dir: &std::path::Path) -> std::io::Result<Vec<u64>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for entry in std::fs::read_dir(&d)? {
            let entry = entry?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else {
                out.push(entry.metadata()?.len());
            }
        }
    }
    Ok(out)
}
