#![allow(dead_code)] // shared across benches; each uses a subset
//! Shared helpers for the figure-regeneration benches.
//!
//! Default scale is a laptop-friendly scale-down of the paper's TR
//! (19.4M vertices / 146 instances on 12 hosts); pass `--full` for a
//! larger run, or override with `--vertices/--instances`. All benches
//! print the paper-figure series as markdown tables (EXPERIMENTS.md
//! records them) and report the disk-model time (`sim`) next to measured
//! wall time — Fig. 6/8 shapes live in the modeled series (DESIGN.md §2.3).

use goffish::cluster::ClusterSpec;
use goffish::datagen::{TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{deploy, DeployConfig, DeployReport, DiskModel, Store, StoreOptions};
use goffish::gopher::GopherEngine;
use goffish::metrics::Metrics;
use goffish::util::bench::BenchArgs;
use std::path::PathBuf;
use std::sync::Arc;

pub const PAPER_HOSTS: usize = 12;

pub struct BenchScale {
    pub vertices: usize,
    pub instances: usize,
    pub traces: usize,
    pub hosts: usize,
}

impl BenchScale {
    pub fn from_args(args: &BenchArgs) -> BenchScale {
        let full = args.flag("full");
        BenchScale {
            vertices: args.usize("vertices", if full { 400_000 } else { 40_000 }),
            instances: args.usize("instances", if full { 146 } else { 48 }),
            traces: args.usize("traces", if full { 4_000 } else { 1_200 }),
            hosts: args.usize("hosts", PAPER_HOSTS),
        }
    }

    pub fn generator(&self) -> TraceRouteGenerator {
        TraceRouteGenerator::new(TraceRouteParams {
            n_vertices: self.vertices,
            n_instances: self.instances,
            traces_per_instance: self.traces,
            ..Default::default()
        })
    }
}

/// Deploy (cached across bench invocations in the target dir) and return
/// the deployment directory + report.
pub fn deploy_cached(
    gen: &TraceRouteGenerator,
    scale: &BenchScale,
    bins: usize,
    pack: usize,
) -> (PathBuf, DeployReport) {
    let cfg = DeployConfig::new(scale.hosts, bins, pack);
    // Cache key includes the slice format version: deployments written by
    // an older binary are not silently reused after a format change.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target/bench-deployments")
        .join(format!(
            "tr-v{}-t{}-p{}-s{bins}-i{pack}-f{}",
            scale.vertices, scale.instances, scale.hosts, cfg.slice_version
        ));
    let stamp = root.join("deploy-report.txt");
    if !stamp.exists() {
        let _ = std::fs::remove_dir_all(&root);
        let report = deploy(gen, &cfg, &root).expect("deploy failed");
        std::fs::write(
            &stamp,
            format!(
                "{} {} {} {} {} {}\n{}\n{}",
                report.n_vertices,
                report.n_edges,
                report.slices_written,
                report.bytes_written,
                report.attr_body_bytes,
                // Edge cut stored in basis points so the head line stays
                // all-integer for the parser below.
                (report.edge_cut_pct * 100.0).round().max(0.0) as u64,
                report
                    .subgraphs_per_partition
                    .iter()
                    .map(|c| c.to_string())
                    .collect::<Vec<_>>()
                    .join(" "),
                report
                    .subgraph_sizes
                    .iter()
                    .map(|(v, e)| format!("{v},{e}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            ),
        )
        .unwrap();
        (root, report)
    } else {
        let text = std::fs::read_to_string(&stamp).unwrap();
        let mut lines = text.lines();
        let head: Vec<u64> =
            lines.next().unwrap().split_whitespace().map(|x| x.parse().unwrap()).collect();
        let per_part: Vec<usize> =
            lines.next().unwrap().split_whitespace().map(|x| x.parse().unwrap()).collect();
        let sizes: Vec<(usize, usize)> = lines
            .next()
            .unwrap()
            .split_whitespace()
            .map(|p| {
                let (v, e) = p.split_once(',').unwrap();
                (v.parse().unwrap(), e.parse().unwrap())
            })
            .collect();
        let report = DeployReport {
            n_parts: scale.hosts,
            n_instances: scale.instances,
            n_vertices: head[0] as usize,
            n_edges: head[1] as usize,
            subgraphs_per_partition: per_part,
            subgraph_sizes: sizes,
            slices_written: head[2] as usize,
            bytes_written: head[3],
            attr_body_bytes: head.get(4).copied().unwrap_or(0),
            edge_cut_pct: head.get(5).map(|&bp| bp as f64 / 100.0).unwrap_or(-1.0),
        };
        (root, report)
    }
}

/// Open all partitions with a given cache size and the HDD disk model.
pub fn open_stores(dir: &PathBuf, hosts: usize, cache: usize, metrics: Arc<Metrics>) -> Vec<Store> {
    let opts = StoreOptions { cache_slots: cache, disk: DiskModel::default(), metrics, ..Default::default() };
    (0..hosts).map(|p| Store::open(dir, p, opts.clone()).expect("open store")).collect()
}

pub fn engine(dir: &PathBuf, hosts: usize, cache: usize) -> (GopherEngine, Arc<Metrics>) {
    let metrics = Arc::new(Metrics::new());
    let stores = open_stores(dir, hosts, cache, metrics.clone());
    (GopherEngine::new(stores, ClusterSpec::new(hosts), metrics.clone()), metrics)
}

/// Paper configuration label.
pub fn cfg_label(bins: usize, pack: usize, cache: usize) -> String {
    format!("s{bins}-i{pack}-c{cache}")
}
