//! Ablation A3 — temporal packing factor sweep (§V-C).
//!
//! "The number or time duration of instances packed into a slice can be
//! tuned." Sweeps i for a sequential time-ordered scan (the access
//! pattern packing optimizes for) and a *random-timestep* scan (the
//! pattern it pessimizes), showing the trade-off.

#[path = "common.rs"]
mod common;

use common::*;
use goffish::gofs::Projection;
use goffish::metrics::Metrics;
use goffish::util::bench::{BenchArgs, Table};
use goffish::util::Prng;
use std::sync::Arc;

fn main() {
    let args = BenchArgs::from_env();
    let scale = BenchScale::from_args(&args);
    let gen = scale.generator();
    let packs: Vec<usize> =
        [1usize, 2, 4, 8, 16, 48].iter().copied().filter(|&i| i <= scale.instances).collect();

    let mut t = Table::new(&[
        "pack (i)", "slices on disk", "seq scan sim (s)", "seq slices read",
        "random-access sim (s)", "random slices read",
    ]);
    for &pack in &packs {
        let (dir, report) = deploy_cached(&gen, &scale, 20, pack);

        // Sequential: every subgraph, every instance in time order.
        let stores = open_stores(&dir, scale.hosts, 14, Arc::new(Metrics::new()));
        for store in &stores {
            let proj = Projection::all(store.vertex_schema(), store.edge_schema());
            for sg in store.subgraphs() {
                for ts in 0..scale.instances {
                    let _ = store.read_instance(sg.id.local(), ts, &proj).unwrap();
                }
            }
        }
        let seq_sim: u64 = stores.iter().map(|s| s.sim_disk_ns()).sum();
        let seq_misses: u64 = stores.iter().map(|s| s.cache_stats().1).sum();

        // Random: same volume of reads at random timesteps.
        let stores = open_stores(&dir, scale.hosts, 14, Arc::new(Metrics::new()));
        let mut rng = Prng::new(42);
        for store in &stores {
            let proj = Projection::all(store.vertex_schema(), store.edge_schema());
            for sg in store.subgraphs() {
                for _ in 0..scale.instances {
                    let ts = rng.gen_range(scale.instances as u64) as usize;
                    let _ = store.read_instance(sg.id.local(), ts, &proj).unwrap();
                }
            }
        }
        let rnd_sim: u64 = stores.iter().map(|s| s.sim_disk_ns()).sum();
        let rnd_misses: u64 = stores.iter().map(|s| s.cache_stats().1).sum();

        t.row(&[
            pack.to_string(),
            report.slices_written.to_string(),
            format!("{:.2}", seq_sim as f64 / 1e9),
            seq_misses.to_string(),
            format!("{:.2}", rnd_sim as f64 / 1e9),
            rnd_misses.to_string(),
        ]);
    }
    t.print("A3 — temporal packing sweep (s20, c14)");
    println!("expected: seq cost falls with i (amortized reads); random access pays for overpacking");
}
