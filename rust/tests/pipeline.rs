//! Whole-pipeline invariants: deployment determinism, config equivalence
//! (results must not depend on s/i/c layout parameters), and host-count
//! independence (distribution must not change answers).

use goffish::apps::SsspApp;
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{deploy, open_collection, DeployConfig, DiskModel, StoreOptions};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("goffish-pipe-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Run SSSP over a deployment; return distances keyed by external id.
fn sssp_distances(dir: &PathBuf, n_parts: usize, cache: usize) -> BTreeMap<u64, i64> {
    let metrics = Arc::new(Metrics::new());
    let opts =
        StoreOptions { cache_slots: cache, disk: DiskModel::instant(), metrics: metrics.clone(), ..Default::default() };
    let stores = open_collection(dir, &opts).unwrap();
    let eng = GopherEngine::new(stores, ClusterSpec::new(n_parts), metrics);
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    eng.run(&app, &RunOptions { timesteps: Some((0..3).collect()), ..Default::default() })
        .unwrap();
    let mut out = BTreeMap::new();
    let distances = app.results.distances.lock().unwrap();
    for store in eng.stores() {
        for sg in &store.shared().subgraphs {
            if let Some((_, d)) = distances.get(&sg.id) {
                for (lv, &ext) in sg.ext_ids.iter().enumerate() {
                    // Quantize to compare across runs robustly.
                    let q = if d[lv].is_finite() { (d[lv] * 100.0).round() as i64 } else { -1 };
                    out.insert(ext, q);
                }
            }
        }
    }
    out
}

#[test]
fn results_independent_of_layout_parameters() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let d1 = tmp("layout-a");
    let d2 = tmp("layout-b");
    // Same partitions, different bins/packing.
    deploy(&gen, &DeployConfig::new(2, 2, 1), &d1).unwrap();
    deploy(&gen, &DeployConfig::new(2, 5, 6), &d2).unwrap();
    let r1 = sssp_distances(&d1, 2, 0);
    let r2 = sssp_distances(&d2, 2, 14);
    assert_eq!(r1, r2, "layout parameters changed application results");
    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

#[test]
fn results_independent_of_host_count() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let d1 = tmp("hosts-1");
    let d4 = tmp("hosts-4");
    deploy(&gen, &DeployConfig::new(1, 3, 4), &d1).unwrap();
    deploy(&gen, &DeployConfig::new(4, 3, 4), &d4).unwrap();
    let r1 = sssp_distances(&d1, 1, 8);
    let r4 = sssp_distances(&d4, 4, 8);
    assert_eq!(
        r1.len(),
        r4.len(),
        "different vertex coverage: {} vs {}",
        r1.len(),
        r4.len()
    );
    assert_eq!(r1, r4, "host count changed application results");
    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d4).unwrap();
}

#[test]
fn deployment_is_deterministic() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let d1 = tmp("det-1");
    let d2 = tmp("det-2");
    let r1 = deploy(&gen, &DeployConfig::new(3, 4, 5), &d1).unwrap();
    let r2 = deploy(&gen, &DeployConfig::new(3, 4, 5), &d2).unwrap();
    assert_eq!(r1.subgraphs_per_partition, r2.subgraphs_per_partition);
    assert_eq!(r1.subgraph_sizes, r2.subgraph_sizes);
    assert_eq!(r1.slices_written, r2.slices_written);
    assert_eq!(r1.bytes_written, r2.bytes_written);
    // Byte-identical template slices.
    let t1 = std::fs::read(d1.join("part-0/template.slice")).unwrap();
    let t2 = std::fs::read(d2.join("part-0/template.slice")).unwrap();
    assert_eq!(t1, t2);
    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

#[test]
fn uncompressed_deployment_also_loads() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = tmp("nocomp");
    let mut cfg = DeployConfig::new(2, 3, 4);
    cfg.compress = false;
    let report = deploy(&gen, &cfg, &dir).unwrap();
    assert!(report.bytes_written > 0);
    let r = sssp_distances(&dir, 2, 8);
    assert_eq!(r.len(), gen.template().n_vertices());
    std::fs::remove_dir_all(&dir).unwrap();
}
