//! End-to-end application correctness: datagen → deploy → Gopher iBSP →
//! results cross-checked against independent single-machine oracles.

use goffish::apps::{NHopApp, PageRankApp, SsspApp, VehicleTrackApp, WccApp};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{
    roadnet, traceroute, CollectionSource, RoadNetGenerator, RoadNetParams, TraceRouteGenerator,
    TraceRouteParams,
};
use goffish::gofs::{deploy, open_collection, DeployConfig, DiskModel, StoreOptions};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::graph::{GraphTemplate, Timestep, VIdx};
use goffish::metrics::Metrics;
use goffish::runtime::ScalarBackend;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("goffish-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn engine_over(dir: &PathBuf, n_parts: usize) -> GopherEngine {
    let metrics = Arc::new(Metrics::new());
    let opts = StoreOptions { cache_slots: 28, disk: DiskModel::instant(), metrics: metrics.clone(), ..Default::default() };
    let stores = open_collection(dir, &opts).unwrap();
    GopherEngine::new(stores, ClusterSpec::new(n_parts), metrics)
}

/// Oracle: Bellman-Ford fixpoint over the whole template with instance-t
/// weights, warm-started from the previous timestep.
fn temporal_sssp_oracle(
    gen: &TraceRouteGenerator,
    source_ext: u64,
    timesteps: usize,
) -> Vec<f32> {
    let t = gen.template();
    let n = t.n_vertices();
    let src = t.ext_ids.iter().position(|&e| e == source_ext).unwrap();
    let mut dist = vec![f32::INFINITY; n];
    dist[src] = 0.0;
    for ts in 0..timesteps {
        let gi = gen.instance(ts);
        // mean latency per template edge (inf when unobserved)
        let w: Vec<f32> = (0..t.n_edges() as u32)
            .map(|e| {
                let vals = gi.edge_values(t, traceroute::eattr::LATENCY_MS, e);
                if vals.is_empty() {
                    f32::INFINITY
                } else {
                    let (mut s, mut c) = (0.0f64, 0usize);
                    for v in vals.iter() {
                        s += v.as_float().unwrap();
                        c += 1;
                    }
                    (s / c as f64) as f32
                }
            })
            .collect();
        // Bellman-Ford to fixpoint.
        loop {
            let mut improved = false;
            for e in 0..t.n_edges() {
                if !w[e].is_finite() {
                    continue;
                }
                let (s, d) = (t.edge_src[e] as usize, t.edge_dst[e] as usize);
                if dist[s].is_finite() && dist[s] + w[e] < dist[d] {
                    dist[d] = dist[s] + w[e];
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
    }
    dist
}

#[test]
fn sssp_matches_temporal_oracle() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = tmp("sssp");
    deploy(&gen, &DeployConfig::new(3, 4, 3), &dir).unwrap();
    let eng = engine_over(&dir, 3);

    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let n_ts = 4usize;
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    let stats = eng
        .run(&app, &RunOptions { timesteps: Some((0..n_ts).collect()), ..Default::default() })
        .unwrap();
    assert_eq!(stats.per_timestep.len(), n_ts);

    let oracle = temporal_sssp_oracle(&gen, source, n_ts);
    // Collect engine distances back to template indexing.
    let mut got = vec![f32::INFINITY; gen.template().n_vertices()];
    let distances = app.results.distances.lock().unwrap();
    for store in eng.stores() {
        for sg in &store.shared().subgraphs {
            if let Some((_, d)) = distances.get(&sg.id) {
                for (lv, &gv) in sg.vertices.iter().enumerate() {
                    got[gv as usize] = d[lv];
                }
            }
        }
    }
    let mut reach_oracle = 0;
    for v in 0..oracle.len() {
        match (got[v].is_finite(), oracle[v].is_finite()) {
            (true, true) => {
                reach_oracle += 1;
                assert!(
                    (got[v] - oracle[v]).abs() <= 1e-2 * (1.0 + oracle[v].abs()),
                    "dist mismatch at v{v}: got {} want {}",
                    got[v],
                    oracle[v]
                );
            }
            (fa, fb) => assert_eq!(fa, fb, "reachability mismatch at v{v}"),
        }
    }
    assert!(reach_oracle > 10, "oracle reaches too few vertices ({reach_oracle})");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sssp_reachability_grows_over_time() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = tmp("sssp-grow");
    deploy(&gen, &DeployConfig::new(2, 3, 4), &dir).unwrap();
    let eng = engine_over(&dir, 2);
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    eng.run(&app, &RunOptions { timesteps: Some((0..6).collect()), ..Default::default() })
        .unwrap();
    // Total reached per timestep must be monotone non-decreasing.
    let reached = app.results.reached.lock().unwrap();
    let total_at = |t: Timestep| -> usize {
        reached.iter().filter(|((ts, _), _)| *ts == t).map(|(_, &c)| c).sum()
    };
    let totals: Vec<usize> = (0..6).map(total_at).collect();
    for w in totals.windows(2) {
        assert!(w[1] >= w[0], "reachability shrank: {totals:?}");
    }
    assert!(totals[5] > totals[0], "no temporal growth: {totals:?}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Oracle: dense synchronous PageRank over the whole template, restricted
/// to active edges of instance `t`.
fn pagerank_oracle(
    template: &GraphTemplate,
    gen: &TraceRouteGenerator,
    t: Timestep,
    iters: usize,
) -> Vec<f32> {
    let n = template.n_vertices();
    let gi = gen.instance(t);
    let active: Vec<bool> = (0..template.n_edges() as u32)
        .map(|e| {
            gi.edge_values(template, traceroute::eattr::ACTIVE, e)
                .first()
                .and_then(|v| v.as_bool())
                .unwrap_or(false)
        })
        .collect();
    let mut out_deg = vec![0u32; n];
    for e in 0..template.n_edges() {
        if active[e] {
            out_deg[template.edge_src[e] as usize] += 1;
        }
    }
    let mut ranks = vec![1.0f32 / n as f32; n];
    let (d, teleport) = (0.85f32, 0.15f32 / n as f32);
    for _ in 0..iters {
        let mut incoming = vec![0.0f32; n];
        for e in 0..template.n_edges() {
            if active[e] {
                let s = template.edge_src[e] as usize;
                incoming[template.edge_dst[e] as usize] += ranks[s] / out_deg[s] as f32;
            }
        }
        for v in 0..n {
            ranks[v] = teleport + d * incoming[v];
        }
    }
    ranks
}

#[test]
fn pagerank_matches_dense_oracle() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = tmp("pr");
    deploy(&gen, &DeployConfig::new(3, 4, 3), &dir).unwrap();
    let eng = engine_over(&dir, 3);
    let n = gen.template().n_vertices();
    let app = PageRankApp::new(n, Some(traceroute::eattr::ACTIVE), Arc::new(ScalarBackend));
    let t = 2usize;
    let stats = eng
        .run(&app, &RunOptions { timesteps: Some(vec![t]), ..Default::default() })
        .unwrap();
    // iterations+1 supersteps
    assert_eq!(stats.per_timestep[0].supersteps, app.iterations + 1);

    let oracle = pagerank_oracle(gen.template(), &gen, t, app.iterations);
    // Compare top ranks and total mass.
    let got_top = app.results.top_k(t, 10);
    let mut want: Vec<(u64, f32)> =
        oracle.iter().enumerate().map(|(v, &r)| (gen.template().ext_ids[v], r)).collect();
    want.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (i, &(gid, gr)) in got_top.iter().enumerate().take(5) {
        let (wid, wr) = want[i];
        assert!(
            (gr - wr).abs() <= 1e-4 * (1.0 + wr.abs()),
            "top-{i} rank mismatch: got {gid}:{gr}, want {wid}:{wr}"
        );
    }
    let mass = app.results.mass(t);
    let want_mass: f64 = oracle.iter().map(|&r| r as f64).sum();
    assert!((mass - want_mass).abs() < 1e-3, "mass {mass} vs {want_mass}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn nhop_merge_composites_across_timesteps() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = tmp("nhop");
    deploy(&gen, &DeployConfig::new(2, 3, 4), &dir).unwrap();
    let eng = engine_over(&dir, 2);
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let mut app = NHopApp::new(source, 4, traceroute::eattr::LATENCY_MS);
    app.hist_hi = 2000.0;
    let n_ts = 3usize;
    let stats = eng
        .run(&app, &RunOptions { timesteps: Some((0..n_ts).collect()), ..Default::default() })
        .unwrap();
    assert!(stats.merge_wall_s >= 0.0);
    let composite = app.results.composite.lock().unwrap();
    let hist = composite.as_ref().expect("merge ran");
    assert!(hist.total() > 0, "no 4-hop arrivals recorded");

    // Oracle for timestep 0: BFS hop counts over observed edges.
    let t = gen.template();
    let gi = gen.instance(0);
    let src = t.ext_ids.iter().position(|&e| e == source).unwrap();
    let mut hops = vec![u32::MAX; t.n_vertices()];
    hops[src] = 0;
    let mut frontier = vec![src as VIdx];
    for h in 1..=4u32 {
        let mut next = Vec::new();
        for &v in &frontier {
            for (u, e) in t.out.out_edges(v) {
                let seen = !gi.edge_values(t, traceroute::eattr::LATENCY_MS, e).is_empty();
                if seen && hops[u as usize] == u32::MAX {
                    hops[u as usize] = h;
                    next.push(u);
                }
            }
        }
        frontier = next;
    }
    let oracle_n4 = hops.iter().filter(|&&h| h == 4).count() as u64;
    // The composite (3 timesteps) must record at least timestep-0's count.
    assert!(
        hist.total() >= oracle_n4,
        "composite {} < timestep-0 oracle {oracle_n4}",
        hist.total()
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn vehicle_tracking_follows_ground_truth() {
    let gen = RoadNetGenerator::new(RoadNetParams::tiny());
    let dir = tmp("track");
    deploy(&gen, &DeployConfig::new(3, 3, 2), &dir).unwrap();
    let eng = engine_over(&dir, 3);

    let vehicle = 7usize;
    let plate = RoadNetGenerator::plate(vehicle);
    let start = gen.trajectory(0, vehicle)[0];
    let start_ext = gen.template().ext_ids[start as usize];
    let app = VehicleTrackApp::new(&plate, start_ext, roadnet::vattr::PLATES);
    eng.run(&app, &RunOptions::default()).unwrap();

    let traj = app.results.trajectory();
    assert!(!traj.is_empty(), "vehicle never found");
    // Every ground-truth position must be sighted in its timestep, and no
    // sighting may occur where the plate never was.
    for t in 0..gen.n_instances() {
        let want: std::collections::HashSet<u64> = gen
            .trajectory(t, vehicle)
            .iter()
            .map(|&v| gen.template().ext_ids[v as usize])
            .collect();
        let got: std::collections::HashSet<u64> =
            traj.iter().filter(|(ts, _)| *ts == t).map(|&(_, v)| v).collect();
        for w in &want {
            assert!(got.contains(w), "timestep {t}: ground-truth position {w} missed");
        }
        for g in &got {
            assert!(want.contains(g), "timestep {t}: spurious sighting {g}");
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn wcc_matches_union_find_oracle() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = tmp("wcc");
    deploy(&gen, &DeployConfig::new(3, 4, 4), &dir).unwrap();
    let eng = engine_over(&dir, 3);
    let app = WccApp::new();
    eng.run(&app, &RunOptions { timesteps: Some(vec![0]), ..Default::default() })
        .unwrap();

    // Union-find oracle over undirected template edges.
    let t = gen.template();
    let n = t.n_vertices();
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(p: &mut Vec<usize>, mut x: usize) -> usize {
        while p[x] != x {
            p[x] = p[p[x]];
            x = p[x];
        }
        x
    }
    for e in 0..t.n_edges() {
        let (a, b) = (
            find(&mut parent, t.edge_src[e] as usize),
            find(&mut parent, t.edge_dst[e] as usize),
        );
        if a != b {
            parent[a] = b;
        }
    }
    let mut oracle_comps: HashMap<usize, u64> = HashMap::new();
    for v in 0..n {
        let r = find(&mut parent, v);
        let e = t.ext_ids[v];
        oracle_comps.entry(r).and_modify(|m| *m = (*m).min(e)).or_insert(e);
    }
    let n_oracle = oracle_comps.len();

    // Engine labels: each subgraph's label must be the min ext id of its
    // union-find component, and distinct label count matches.
    let labels = app.results.labels.lock().unwrap();
    let mut got_labels: std::collections::HashSet<u64> = Default::default();
    for store in eng.stores() {
        for sg in &store.shared().subgraphs {
            let label = labels[&sg.id];
            got_labels.insert(label);
            let root = find(&mut parent, sg.vertices[0] as usize);
            assert_eq!(label, oracle_comps[&root], "label mismatch for {}", sg.id);
        }
    }
    assert_eq!(got_labels.len(), n_oracle);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pr_stability_merge_reports_drift() {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = tmp("prstab");
    deploy(&gen, &DeployConfig::new(2, 3, 4), &dir).unwrap();
    let eng = engine_over(&dir, 2);
    let app = goffish::apps::PrStabilityApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    eng.run(
        &app,
        &goffish::gopher::RunOptions { timesteps: Some((0..5).collect()), ..Default::default() },
    )
    .unwrap();
    let report = app.results.report.lock().unwrap();
    let report = report.as_ref().expect("merge ran");
    assert_eq!(report.n_timesteps, 5);
    assert_eq!(report.per_subgraph.len(), eng.n_subgraphs());
    // Mass drifts across instances (active edges differ per window), and
    // every mean mass is positive.
    assert!(report.per_subgraph.iter().all(|(_, mean, _)| *mean > 0.0));
    let unstable = report.unstable(0.05);
    assert!(!unstable.is_empty(), "no drift detected across instances");
    // Per-instance PR mass is bounded by 1 in total.
    let total_mean: f64 = report.per_subgraph.iter().map(|(_, m, _)| m).sum();
    assert!(total_mean <= 1.0 + 1e-6, "mass {total_mean}");
    std::fs::remove_dir_all(&dir).unwrap();
}
