//! End-to-end bit-identity of real multi-process distribution
//! (`cluster::coordinator` + `cluster::worker` over TCP) against the
//! in-process engine: same collection, same application, byte-equal
//! canonical output — batch and follow mode — plus crash/rejoin.
//!
//! The in-process expectation is built by running the unchanged engine
//! over all partitions at once and replaying the *same* per-host
//! emission (`DistApp::emit_timestep`) in host order, which is exactly
//! how the coordinator assembles the cluster-wide output.

use goffish::cluster::coordinator::{run_coordinator, CoordinatorConfig};
use goffish::cluster::worker::{build_app, run_host, HostConfig};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{
    deploy, open_collection, repartition_collection, DeployConfig, DiskModel,
    RepartitionOptions, StoreOptions,
};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::graph::SubgraphId;
use goffish::metrics::{keys, Metrics};
use goffish::partition::PartitionStrategy;
use goffish::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

const N_HOSTS: usize = 2;

fn deployed(tag: &str) -> (TraceRouteGenerator, PathBuf) {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = std::env::temp_dir().join(format!("goffish-dist-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    deploy(&gen, &DeployConfig::new(N_HOSTS, 4, 3), &dir).unwrap();
    (gen, dir)
}

fn store_opts() -> StoreOptions {
    StoreOptions { cache_slots: 16, disk: DiskModel::instant(), ..Default::default() }
}

fn sssp_params(gen: &TraceRouteGenerator) -> Vec<(String, String)> {
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    vec![("source".to_string(), source.to_string())]
}

/// The ground truth: one in-process run over every partition, emitted
/// through the same `DistApp` the workers use — per host in store
/// order, hosts concatenated in host order, timestep-major.
fn expected_output(dir: &Path, app_name: &str, params: &[(String, String)]) -> String {
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions { metrics: metrics.clone(), ..store_opts() };
    let stores = open_collection(dir, &o).unwrap();
    assert_eq!(stores.len(), N_HOSTS);
    let per_host_sgids: Vec<Vec<SubgraphId>> = stores
        .iter()
        .map(|s| s.shared().subgraphs.iter().map(|sg| sg.id).collect())
        .collect();
    let total_vertices: usize = stores
        .iter()
        .map(|s| s.shared().subgraphs.iter().map(|g| g.n_vertices()).sum::<usize>())
        .sum();
    let n_t = stores[0].n_instances();
    let app = build_app(app_name, params, total_vertices, stores[0].as_ref()).unwrap();
    let eng = GopherEngine::new(stores, ClusterSpec::new(N_HOSTS), metrics);
    eng.run(app.as_app(), &RunOptions::default()).unwrap();
    let mut out = String::new();
    for t in 0..n_t {
        for sgids in &per_host_sgids {
            out.push_str(&app.emit_timestep(t, sgids));
        }
    }
    out
}

fn wait_port(pf: &Path) -> u16 {
    let t0 = Instant::now();
    loop {
        if let Ok(s) = std::fs::read_to_string(pf) {
            if let Ok(p) = s.trim().parse() {
                return p;
            }
        }
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "coordinator never wrote its port file"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Coordinator + one worker thread per partition, all over localhost
/// TCP in this process. Returns the coordinator's assembled output.
fn run_cluster(
    dir: &Path,
    app_name: &str,
    params: Vec<(String, String)>,
    follow: bool,
    tag: &str,
    metrics_out: Option<PathBuf>,
) -> String {
    let port_file = dir.join(format!("port-{tag}"));
    let cfg = CoordinatorConfig {
        n_hosts: N_HOSTS,
        listen: "127.0.0.1:0".to_string(),
        port_file: Some(port_file.clone()),
        app_name: app_name.to_string(),
        app_params: params,
        follow,
        // A sealed collection never grows: drain the poll budget fast.
        follow_poll_ms: 1,
        follow_idle_polls: 3,
        metrics_out,
        ..Default::default()
    };
    let coord = std::thread::spawn(move || run_coordinator(&cfg));
    let addr = format!("127.0.0.1:{}", wait_port(&port_file));
    let hosts: Vec<_> = (0..N_HOSTS)
        .map(|part| {
            let cfg = HostConfig {
                root: dir.to_path_buf(),
                part,
                coordinator: addr.clone(),
                store_opts: store_opts(),
                ..Default::default()
            };
            std::thread::spawn(move || run_host(&cfg))
        })
        .collect();
    for (part, h) in hosts.into_iter().enumerate() {
        h.join().unwrap().unwrap_or_else(|e| panic!("host {part} failed: {e:#}"));
    }
    coord.join().unwrap().expect("coordinator failed")
}

#[test]
fn sssp_two_host_run_is_bit_identical_to_in_process() {
    let (gen, dir) = deployed("sssp");
    let params = sssp_params(&gen);
    let expected = expected_output(&dir, "sssp", &params);
    // One line per subgraph per timestep — the emission is total, so a
    // silently skipped partition or timestep cannot pass.
    assert!(!expected.is_empty());
    let actual = run_cluster(&dir, "sssp", params, false, "sssp", None);
    assert_eq!(actual, expected, "distributed SSSP output diverged from in-process");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pagerank_two_host_run_is_bit_identical_to_in_process() {
    let (_gen, dir) = deployed("pr");
    let expected = expected_output(&dir, "pagerank", &[]);
    assert!(!expected.is_empty());
    let actual = run_cluster(&dir, "pagerank", Vec::new(), false, "pr", None);
    assert_eq!(actual, expected, "distributed PageRank output diverged from in-process");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Metric parity: the deterministic counters in the coordinator's
/// `RUN_METRICS.json` must agree with an in-process run over the same
/// collection. Supersteps and timesteps advance in lockstep, so every
/// host's count equals the single-engine count exactly; slice reads are
/// partitioned, so they must *sum* to the single-engine total. This
/// pins down the whole shipping path — worker snapshot encode, piggyback
/// on Heartbeat/Commit frames, coordinator aggregation, JSON dump.
#[test]
fn cluster_metrics_agree_with_in_process_counters() {
    let (gen, dir) = deployed("parity");
    let params = sssp_params(&gen);

    // In-process ground truth, counters captured from the run's registry.
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions { metrics: metrics.clone(), ..store_opts() };
    let stores = open_collection(&dir, &o).unwrap();
    let total_vertices: usize = stores
        .iter()
        .map(|s| s.shared().subgraphs.iter().map(|g| g.n_vertices()).sum::<usize>())
        .sum();
    let app = build_app("sssp", &params, total_vertices, stores[0].as_ref()).unwrap();
    let eng = GopherEngine::new(stores, ClusterSpec::new(N_HOSTS), metrics.clone());
    eng.run(app.as_app(), &RunOptions::default()).unwrap();
    let exp_supersteps = metrics.get(keys::SUPERSTEPS);
    let exp_timesteps = metrics.get(keys::TIMESTEPS);
    let exp_slices = metrics.get(keys::SLICES_READ);
    assert!(exp_supersteps > 0 && exp_timesteps > 0 && exp_slices > 0);

    let mpath = dir.join("RUN_METRICS.json");
    run_cluster(&dir, "sssp", params, false, "parity", Some(mpath.clone()));

    let doc = Json::parse(&std::fs::read_to_string(&mpath).unwrap()).unwrap();
    assert_eq!(doc.get("n_hosts").and_then(|v| v.as_u64()), Some(N_HOSTS as u64));
    let hosts = doc.get("hosts").expect("dump has no hosts block");
    let counter = |h: &str, k: &str| -> u64 {
        hosts
            .get(h)
            .and_then(|b| b.get("counters"))
            .and_then(|c| c.get(k))
            .and_then(|v| v.as_u64())
            .unwrap_or(0)
    };
    for h in ["0", "1"] {
        assert_eq!(
            counter(h, keys::SUPERSTEPS),
            exp_supersteps,
            "host {h} superstep count diverged from in-process"
        );
        assert_eq!(
            counter(h, keys::TIMESTEPS),
            exp_timesteps,
            "host {h} timestep count diverged from in-process"
        );
    }
    assert_eq!(
        counter("0", keys::SLICES_READ) + counter("1", keys::SLICES_READ),
        exp_slices,
        "summed per-host slice reads diverged from in-process"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Follow mode over the temporal pool pattern (PageRank is
/// `Independent`): the refresh watermark is the minimum visible count
/// over the workers, and on a sealed collection the run must drain
/// every published timestep and then end — with the same bytes as a
/// batch run.
#[test]
fn pagerank_follow_run_drains_the_collection_bit_identically() {
    let (_gen, dir) = deployed("follow");
    let expected = expected_output(&dir, "pagerank", &[]);
    let actual = run_cluster(&dir, "pagerank", Vec::new(), true, "follow", None);
    assert_eq!(actual, expected, "distributed follow run diverged from in-process");
    std::fs::remove_dir_all(&dir).unwrap();
}

fn wait_exit(child: &mut std::process::Child, budget: Duration) -> std::process::ExitStatus {
    let t0 = Instant::now();
    loop {
        if let Some(status) = child.try_wait().unwrap() {
            return status;
        }
        if t0.elapsed() > budget {
            let _ = child.kill();
            panic!("process did not exit within {budget:?}");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// The crash window: SIGKILL one host process mid-run, restart it with
/// the same flags, and require the run to complete with output
/// byte-identical to the in-process run — the rejoin path (durable
/// store + carry checkpoint at the last committed barrier) must be
/// invisible in the result.
#[test]
fn killed_host_rejoins_and_reproduces_the_batch_output() {
    let bin = env!("CARGO_BIN_EXE_goffish");
    let (gen, dir) = deployed("kill");
    let params = sssp_params(&gen);
    let expected = expected_output(&dir, "sssp", &params);
    let port_file = dir.join("port");
    let out_file = dir.join("out.txt");

    let mut coord = std::process::Command::new(bin)
        .args(["coordinator", "--hosts", "2", "--app", "sssp"])
        .args(["--source", &params[0].1, "--listen", "127.0.0.1:0"])
        .arg("--port-file")
        .arg(&port_file)
        .arg("--out")
        .arg(&out_file)
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();
    let addr = format!("127.0.0.1:{}", wait_port(&port_file));
    let spawn_host = |part: usize| {
        std::process::Command::new(bin)
            .arg("host")
            .arg("--store")
            .arg(&dir)
            .args(["--part", &part.to_string(), "--connect", &addr])
            // Slow the barrier down so the kill lands mid-run: ≥ 2
            // supersteps per timestep × 12 timesteps × 25 ms ≫ the kill
            // delay below.
            .args(["--step-delay-ms", "25"])
            .stderr(std::process::Stdio::null())
            .spawn()
            .unwrap()
    };
    let mut h0 = spawn_host(0);
    let mut h1 = spawn_host(1);

    std::thread::sleep(Duration::from_millis(350));
    h1.kill().unwrap(); // SIGKILL: no cleanup, the hard crash
    let _ = h1.wait();
    let mut h1b = spawn_host(1);

    let status = wait_exit(&mut coord, Duration::from_secs(120));
    // Clean up workers before asserting so a failure can't leak them.
    let h0_status = wait_exit(&mut h0, Duration::from_secs(30));
    let h1b_status = wait_exit(&mut h1b, Duration::from_secs(30));
    assert!(status.success(), "coordinator exited with {status}");
    assert!(h0_status.success(), "surviving host exited with {h0_status}");
    assert!(h1b_status.success(), "rejoined host exited with {h1b_status}");

    let actual = std::fs::read_to_string(&out_file).unwrap();
    assert_eq!(actual, expected, "kill + rejoin changed the run output");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A hung host — joined, then silent forever, no heartbeats — must be
/// detected by the coordinator's round deadline and abort the run within
/// bounded time, instead of wedging the barrier forever (the pre-PR 7
/// behavior). The fake worker speaks just enough protocol to join.
#[test]
fn hung_host_is_detected_by_the_round_deadline() {
    use goffish::cluster::proto::{read_msg, write_msg, Msg};
    let dir = std::env::temp_dir().join(format!("goffish-dist-hang-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let port_file = dir.join("port");
    let cfg = CoordinatorConfig {
        n_hosts: 1,
        listen: "127.0.0.1:0".to_string(),
        port_file: Some(port_file.clone()),
        app_name: "sssp".to_string(),
        heartbeat_ms: 50,
        round_deadline_ms: 400,
        join_deadline_ms: 10_000,
        max_epochs: 1,
        ..Default::default()
    };
    let coord = std::thread::spawn(move || run_coordinator(&cfg));
    let addr = format!("127.0.0.1:{}", wait_port(&port_file));
    let hung = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        let hello =
            Msg::Hello { part: 0, n_instances: 1, n_vertices: 4, sgids: vec![0, 1] };
        write_msg(&mut s, &hello).unwrap();
        // Absorb Start / heartbeats / the Abort, answer nothing, hold
        // the socket open until the coordinator hangs up on us.
        while read_msg(&mut s).is_ok() {}
    });
    let t0 = Instant::now();
    let err = coord.join().unwrap().expect_err("a silent host must fail the run");
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "hang detection took {:?} — the deadline did not fire",
        t0.elapsed()
    );
    assert!(err.to_string().contains("giving up"), "unexpected error: {err:#}");
    hung.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Chaos acceptance, batch: a 2-host SSSP run where host 1 lives under
/// `goffish supervise` with a seeded fault plan (send delays, a
/// corrupted Superstep frame, a corrupted heartbeat) and is additionally
/// SIGKILLed mid-run through the supervisor's child pid file. The
/// supervisor must respawn it, the rejoin path must replay from the
/// durable checkpoint, and the final output must be byte-identical to
/// the failure-free in-process run.
#[test]
fn chaos_sssp_supervised_host_survives_kill_and_faults() {
    let bin = env!("CARGO_BIN_EXE_goffish");
    let (gen, dir) = deployed("chaos-sssp");
    let params = sssp_params(&gen);
    let expected = expected_output(&dir, "sssp", &params);
    let port_file = dir.join("port");
    let out_file = dir.join("out.txt");
    let pid_file = dir.join("host1.pid");
    let plan_file = dir.join("faults.plan");
    std::fs::write(
        &plan_file,
        "seed 11\n\
         on host1.send.Superstep nth 5 delay 40\n\
         on host1.send.Superstep nth 9 corrupt\n\
         on host1.send.Heartbeat nth 3 corrupt\n\
         on host1.connect nth 2 delay 30\n",
    )
    .unwrap();

    let mut coord = std::process::Command::new(bin)
        .args(["coordinator", "--hosts", "2", "--app", "sssp"])
        .args(["--source", &params[0].1, "--listen", "127.0.0.1:0"])
        .args(["--heartbeat-ms", "100", "--round-deadline-ms", "2000"])
        .args(["--join-deadline-ms", "60000"])
        .arg("--port-file")
        .arg(&port_file)
        .arg("--out")
        .arg(&out_file)
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();
    let addr = format!("127.0.0.1:{}", wait_port(&port_file));
    let mut h0 = std::process::Command::new(bin)
        .arg("host")
        .arg("--store")
        .arg(&dir)
        .args(["--part", "0", "--connect", &addr, "--step-delay-ms", "25"])
        .args(["--heartbeat-ms", "100", "--round-deadline-ms", "10000"])
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();
    let mut h1 = std::process::Command::new(bin)
        .arg("supervise")
        .arg("--store")
        .arg(&dir)
        .args(["--part", "1", "--connect", &addr, "--step-delay-ms", "25"])
        .args(["--heartbeat-ms", "100", "--round-deadline-ms", "10000"])
        .arg("--fault-plan")
        .arg(&plan_file)
        .args(["--max-restarts", "8", "--restart-backoff-ms", "100"])
        .arg("--child-pid-file")
        .arg(&pid_file)
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();

    // SIGKILL the *current* child (the supervisor republishes the pid
    // after each respawn) once the run is under way.
    std::thread::sleep(Duration::from_millis(450));
    let pid = std::fs::read_to_string(&pid_file)
        .expect("supervisor never published its child pid")
        .trim()
        .to_string();
    let _ = std::process::Command::new("kill").args(["-9", &pid]).status().unwrap();

    let status = wait_exit(&mut coord, Duration::from_secs(180));
    let h0_status = wait_exit(&mut h0, Duration::from_secs(60));
    let h1_status = wait_exit(&mut h1, Duration::from_secs(60));
    assert!(status.success(), "coordinator exited with {status}");
    assert!(h0_status.success(), "fault-free host exited with {h0_status}");
    assert!(h1_status.success(), "supervised host exited with {h1_status}");

    let actual = std::fs::read_to_string(&out_file).unwrap();
    assert_eq!(actual, expected, "chaos SSSP output diverged from in-process");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Chaos acceptance, follow mode (PageRank, the `Independent` pool
/// pattern): host 1's fault plan repeatedly kills its own process right
/// before a Commit (`exit 70`, re-armed on every respawn because each
/// incarnation evaluates the plan afresh), plus a delayed Superstep and
/// a corrupted heartbeat. The supervisor keeps respawning it and the
/// drained follow output must match the in-process run byte for byte.
#[test]
fn chaos_pagerank_follow_supervised_host_survives_repeated_crashes() {
    let bin = env!("CARGO_BIN_EXE_goffish");
    let (_gen, dir) = deployed("chaos-follow");
    let expected = expected_output(&dir, "pagerank", &[]);
    let port_file = dir.join("port");
    let out_file = dir.join("out.txt");
    let plan_file = dir.join("faults.plan");
    std::fs::write(
        &plan_file,
        "seed 23\n\
         on host1.send.Superstep nth 6 delay 40\n\
         on host1.send.Heartbeat nth 2 corrupt\n\
         on host1.send.Commit nth 4 exit 70\n",
    )
    .unwrap();

    let mut coord = std::process::Command::new(bin)
        .args(["coordinator", "--hosts", "2", "--app", "pagerank", "--follow"])
        .args(["--listen", "127.0.0.1:0", "--poll-ms", "5", "--idle-polls", "40"])
        .args(["--heartbeat-ms", "100", "--round-deadline-ms", "5000"])
        .arg("--port-file")
        .arg(&port_file)
        .arg("--out")
        .arg(&out_file)
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();
    let addr = format!("127.0.0.1:{}", wait_port(&port_file));
    let mut h0 = std::process::Command::new(bin)
        .arg("host")
        .arg("--store")
        .arg(&dir)
        .args(["--part", "0", "--connect", &addr, "--step-delay-ms", "10"])
        .args(["--heartbeat-ms", "100", "--round-deadline-ms", "10000"])
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();
    let mut h1 = std::process::Command::new(bin)
        .arg("supervise")
        .arg("--store")
        .arg(&dir)
        .args(["--part", "1", "--connect", &addr, "--step-delay-ms", "10"])
        .args(["--heartbeat-ms", "100", "--round-deadline-ms", "10000"])
        .arg("--fault-plan")
        .arg(&plan_file)
        .args(["--max-restarts", "10", "--restart-backoff-ms", "100"])
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();

    let status = wait_exit(&mut coord, Duration::from_secs(180));
    let h0_status = wait_exit(&mut h0, Duration::from_secs(60));
    let h1_status = wait_exit(&mut h1, Duration::from_secs(60));
    assert!(status.success(), "coordinator exited with {status}");
    assert!(h0_status.success(), "fault-free host exited with {h0_status}");
    assert!(h1_status.success(), "supervised host exited with {h1_status}");

    let actual = std::fs::read_to_string(&out_file).unwrap();
    assert_eq!(actual, expected, "chaos follow output diverged from in-process");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ==================== partitioner coverage (PR 10) ====================

fn deployed_as(tag: &str, strategy: PartitionStrategy) -> (TraceRouteGenerator, PathBuf) {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = std::env::temp_dir().join(format!("goffish-dist-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = DeployConfig::new(N_HOSTS, 4, 3);
    cfg.partition.strategy = strategy;
    deploy(&gen, &cfg, &dir).unwrap();
    (gen, dir)
}

/// The 2-host protocol must be placement-agnostic: on fennel- and
/// binpack-partitioned deployments the cluster output stays byte-equal
/// to the in-process reference over the same store. (Cross-partitioner
/// equality of the *analytics* is pinned by `tests/determinism.rs` —
/// the emission here is keyed by placement-dependent subgraph ids, so
/// each deployment is compared against its own reference.)
#[test]
fn fennel_and_binpack_two_host_runs_match_in_process() {
    for strategy in [PartitionStrategy::Fennel, PartitionStrategy::Binpack] {
        let tag = format!("sssp-{}", strategy.name());
        let (gen, dir) = deployed_as(&tag, strategy);
        let params = sssp_params(&gen);
        let expected = expected_output(&dir, "sssp", &params);
        assert!(!expected.is_empty());
        let actual = run_cluster(&dir, "sssp", params, false, &tag, None);
        assert_eq!(
            actual,
            expected,
            "{}: distributed SSSP diverged from in-process",
            strategy.name()
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

/// Follow mode across a re-partitioning compaction: the collection is
/// re-partitioned offline (fennel layout → ldg re-placement, every part
/// rebuilt and swapped publish-last), then a 2-host follow run must
/// drain the rebuilt collection bit-identically to the in-process
/// reference over the swapped store.
#[test]
fn follow_run_after_repartition_drains_bit_identically() {
    let (_gen, dir) = deployed_as("repart-follow", PartitionStrategy::Fennel);
    let rep = repartition_collection(
        &dir,
        &RepartitionOptions {
            strategy: Some(PartitionStrategy::Ldg),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(
        rep.moved_vertices > 0,
        "ldg re-placement unexpectedly identical to the fennel layout"
    );
    let expected = expected_output(&dir, "pagerank", &[]);
    assert!(!expected.is_empty());
    let actual = run_cluster(&dir, "pagerank", Vec::new(), true, "repart-follow", None);
    assert_eq!(actual, expected, "follow run over a re-partitioned store diverged");
    std::fs::remove_dir_all(&dir).unwrap();
}
