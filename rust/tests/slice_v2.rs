//! Format-v2 attribute slices: compression acceptance, v1 backward
//! compatibility, and bit-identical app outputs across formats and
//! prefetch modes.

use goffish::apps::{PageRankApp, SsspApp};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{deploy, open_collection, DeployConfig, DiskModel, StoreOptions};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use goffish::runtime::ScalarBackend;
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gofs-v2-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tr_gen(instances: usize) -> TraceRouteGenerator {
    TraceRouteGenerator::new(TraceRouteParams {
        n_instances: instances,
        ..TraceRouteParams::tiny()
    })
}

fn deploy_version(
    gen: &TraceRouteGenerator,
    tag: &str,
    version: u8,
    bins: usize,
    pack: usize,
    compress: bool,
) -> (PathBuf, goffish::gofs::DeployReport) {
    let dir = tmpdir(tag);
    let mut cfg = DeployConfig::new(2, bins, pack);
    cfg.slice_version = version;
    cfg.compress = compress;
    let report = deploy(gen, &cfg, &dir).unwrap();
    (dir, report)
}

fn make_engine(dir: &PathBuf, cache: usize) -> GopherEngine {
    let metrics = Arc::new(Metrics::new());
    let opts = StoreOptions {
        cache_slots: cache,
        disk: DiskModel::instant(),
        metrics: metrics.clone(),
        ..Default::default()
    };
    let stores = open_collection(dir, &opts).unwrap();
    let n = stores.len();
    GopherEngine::new(stores, ClusterSpec::new(n), metrics)
}

/// Acceptance: at the paper's s20-i20 layout, v2 attribute bodies must be
/// at least 1.5x smaller than v1 for the traceroute dataset, and the
/// deployment must be smaller on disk.
#[test]
fn v2_shrinks_traceroute_s20_i20_bodies_at_least_1_5x() {
    let gen = tr_gen(20);
    let (d1, r1) = deploy_version(&gen, "ratio-v1", 1, 20, 20, false);
    let (d2, r2) = deploy_version(&gen, "ratio-v2", 2, 20, 20, false);
    assert!(r1.attr_body_bytes > 0 && r2.attr_body_bytes > 0);
    let ratio = r1.attr_body_bytes as f64 / r2.attr_body_bytes as f64;
    assert!(
        ratio >= 1.5,
        "v2 body reduction only {ratio:.2}x (v1 {} vs v2 {})",
        r1.attr_body_bytes,
        r2.attr_body_bytes
    );
    assert!(
        r2.bytes_written < r1.bytes_written,
        "v2 on-disk {} not smaller than v1 {}",
        r2.bytes_written,
        r1.bytes_written
    );
    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

/// Backward compatibility: a v1-format deployment (the wire fixture) must
/// read back exactly the generator's values through the new reader.
#[test]
fn v1_fixture_reads_back_generator_values() {
    let gen = tr_gen(8);
    let (dir, _) = deploy_version(&gen, "fixture-v1", 1, 3, 4, true);
    let opts = StoreOptions {
        cache_slots: 8,
        disk: DiskModel::instant(),
        metrics: Arc::new(Metrics::new()),
        ..Default::default()
    };
    let stores = open_collection(&dir, &opts).unwrap();
    let t = 3usize;
    let gi = gen.instance(t);
    let proj = goffish::gofs::Projection::all(
        &gen.template().vertex_schema,
        &gen.template().edge_schema,
    );
    let mut checked = 0usize;
    for store in &stores {
        for sg in store.subgraphs() {
            let sgi = store.read_instance(sg.id.local(), t, &proj).unwrap();
            for (local, &global) in sg.vertices.iter().enumerate() {
                let got = sgi.vertex_values(traceroute::vattr::RTT_MS, local as u32);
                let want = gi.vertex_values(gen.template(), traceroute::vattr::RTT_MS, global);
                assert_eq!(got.len(), want.len(), "rtt count v{global}");
                assert_eq!(got.first(), want.first(), "rtt first v{global}");
                if !got.is_empty() {
                    checked += 1;
                }
            }
            for (pos, &eidx) in sg.edges.iter().enumerate() {
                let got = sgi.edge_values(traceroute::eattr::LATENCY_MS, pos);
                let want = gi.edge_values(gen.template(), traceroute::eattr::LATENCY_MS, eidx);
                assert_eq!(got.len(), want.len(), "lat count e{eidx}");
                assert_eq!(got.first(), want.first(), "lat first e{eidx}");
            }
        }
    }
    assert!(checked > 10, "too few values checked ({checked})");
    std::fs::remove_dir_all(&dir).unwrap();
}

fn sssp_fp(dir: &PathBuf, prefetch: bool) -> Vec<(u64, usize, u64)> {
    let eng = make_engine(dir, 14);
    let gen = tr_gen(8);
    let src = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(src, traceroute::eattr::LATENCY_MS);
    eng.run(&app, &RunOptions { prefetch, ..Default::default() }).unwrap();
    let distances = app.results.distances.lock().unwrap();
    let mut fp: Vec<(u64, usize, u64)> = distances
        .iter()
        .flat_map(|(sgid, (t, d))| {
            d.iter()
                .enumerate()
                .map(move |(lv, &x)| (sgid.0, *t * 1_000_000 + lv, x.to_bits() as u64))
        })
        .collect();
    fp.sort_unstable();
    fp
}

fn pagerank_fp(dir: &PathBuf) -> Vec<(usize, u64, u64, Vec<(u64, u32)>)> {
    let eng = make_engine(dir, 14);
    let gen = tr_gen(8);
    let app = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    eng.run(&app, &RunOptions::default()).unwrap();
    let by_sg = app.results.by_subgraph.lock().unwrap();
    let mut fp: Vec<(usize, u64, u64, Vec<(u64, u32)>)> = by_sg
        .iter()
        .map(|((t, sgid), s)| {
            (
                *t,
                sgid.0,
                s.mass.to_bits(),
                s.top.iter().map(|&(v, r)| (v, r.to_bits())).collect(),
            )
        })
        .collect();
    fp.sort();
    fp
}

/// Acceptance: SSSP and PageRank outputs are bit-identical across v1/v2
/// slice formats and prefetch on/off.
#[test]
fn sssp_and_pagerank_outputs_bit_identical_across_formats_and_prefetch() {
    let gen = tr_gen(8);
    let (d1, _) = deploy_version(&gen, "apps-v1", 1, 4, 3, true);
    let (d2, _) = deploy_version(&gen, "apps-v2", 2, 4, 3, true);

    let s_v1_pf = sssp_fp(&d1, true);
    let s_v1_np = sssp_fp(&d1, false);
    let s_v2_pf = sssp_fp(&d2, true);
    let s_v2_np = sssp_fp(&d2, false);
    assert!(!s_v1_pf.is_empty());
    assert_eq!(s_v1_pf, s_v1_np, "prefetch changed SSSP outputs (v1)");
    assert_eq!(s_v2_pf, s_v2_np, "prefetch changed SSSP outputs (v2)");
    assert_eq!(s_v1_pf, s_v2_pf, "slice format changed SSSP outputs");

    let p_v1 = pagerank_fp(&d1);
    let p_v2 = pagerank_fp(&d2);
    assert!(!p_v1.is_empty());
    assert_eq!(p_v1, p_v2, "slice format changed PageRank outputs");

    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

/// Tentpole propcheck (zero-copy cell slabs): across random layouts,
/// read orders and cache pressure, (a) cells of the same position block
/// alias ONE shared slab across the group's timesteps, (b) instances
/// held across cache eviction keep reading values identical to an
/// unevicted reference store, and (c) a store under heavy eviction
/// never multiply-accounts a shared slab (resident bytes stay sane).
#[test]
fn arc_slab_views_alias_across_lazy_decode_and_eviction() {
    use goffish::gofs::Projection;
    use goffish::util::propcheck::forall;
    forall(6, |g| {
        let pack = g.usize(2..5);
        let bins = g.usize(1..4);
        let n = g.usize(4..9);
        let gen = tr_gen(n);
        let dir = tmpdir(&format!("alias-{pack}-{bins}-{n}-{}", g.usize(0..1_000_000)));
        let mut cfg = DeployConfig::new(2, bins, pack);
        cfg.slice_version = 2;
        deploy(&gen, &cfg, &dir).unwrap();

        // Tiny cache: every other slice read evicts the previous one.
        let squeezed = StoreOptions {
            cache_slots: 1,
            disk: DiskModel::instant(),
            metrics: Arc::new(Metrics::new()),
            ..Default::default()
        };
        let reference = open_collection(&dir, &StoreOptions {
            cache_slots: 4096,
            disk: DiskModel::instant(),
            metrics: Arc::new(Metrics::new()),
            ..Default::default()
        })
        .unwrap();
        let stores = open_collection(&dir, &squeezed).unwrap();
        for (store, refstore) in stores.iter().zip(&reference) {
            let proj = Projection::all(store.vertex_schema(), store.edge_schema());
            // Pick one subgraph and one packed group to hold across the
            // churn below.
            let sgs = store.subgraphs();
            let sg = &sgs[g.usize(0..sgs.len())];
            let group = g.usize(0..n.div_ceil(pack));
            let t_lo = group * pack;
            let t_hi = (t_lo + pack).min(n);
            // Aliasing: on the roomy store (one decode per slice), cells
            // of the same position block at different timesteps must be
            // views into ONE shared slab.
            let ref_held: Vec<_> = (t_lo..t_hi)
                .map(|t| refstore.read_instance(sg.id.local(), t, &proj).unwrap())
                .collect();
            for attr in 0..store.edge_schema().len() {
                let cols: Vec<_> =
                    ref_held.iter().filter_map(|sgi| sgi.edge_column(attr)).collect();
                for w in cols.windows(2) {
                    assert!(
                        w[0].shares_backing(w[1]),
                        "edge attr {attr}: cells of one decoded group must share a slab"
                    );
                }
            }
            // Liveness: hold instances from the 1-slot store while a
            // full scan evicts and re-decodes their slices many times
            // over — the held views' Arc'd slabs must keep every value
            // readable and correct.
            let held: Vec<_> = (t_lo..t_hi)
                .map(|t| store.read_instance(sg.id.local(), t, &proj).unwrap())
                .collect();
            for t in 0..n {
                for other in &sgs {
                    let _ = store.read_instance(other.id.local(), t, &proj).unwrap();
                }
            }
            let (_, _, evictions) = store.cache_stats();
            assert!(evictions > 0, "scan must churn the 1-slot cache");
            // Held views still read exactly what the reference store
            // (no eviction) reads.
            for (t, sgi) in (t_lo..t_hi).zip(&held) {
                let want = refstore.read_instance(sg.id.local(), t, &proj).unwrap();
                for attr in 0..store.vertex_schema().len() {
                    for v in 0..sg.n_vertices() as u32 {
                        assert_eq!(
                            sgi.vertex_values(attr, v),
                            want.vertex_values(attr, v),
                            "post-eviction vattr {attr} v{v} t{t}"
                        );
                    }
                }
                for attr in 0..store.edge_schema().len() {
                    for e in 0..sg.edges.len() {
                        assert_eq!(
                            sgi.edge_values(attr, e),
                            want.edge_values(attr, e),
                            "post-eviction eattr {attr} e{e} t{t}"
                        );
                    }
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    });
}
