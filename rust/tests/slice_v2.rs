//! Format-v2 attribute slices: compression acceptance, v1 backward
//! compatibility, and bit-identical app outputs across formats and
//! prefetch modes.

use goffish::apps::{PageRankApp, SsspApp};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{deploy, open_collection, DeployConfig, DiskModel, StoreOptions};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use goffish::runtime::ScalarBackend;
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gofs-v2-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tr_gen(instances: usize) -> TraceRouteGenerator {
    TraceRouteGenerator::new(TraceRouteParams {
        n_instances: instances,
        ..TraceRouteParams::tiny()
    })
}

fn deploy_version(
    gen: &TraceRouteGenerator,
    tag: &str,
    version: u8,
    bins: usize,
    pack: usize,
    compress: bool,
) -> (PathBuf, goffish::gofs::DeployReport) {
    let dir = tmpdir(tag);
    let mut cfg = DeployConfig::new(2, bins, pack);
    cfg.slice_version = version;
    cfg.compress = compress;
    let report = deploy(gen, &cfg, &dir).unwrap();
    (dir, report)
}

fn make_engine(dir: &PathBuf, cache: usize) -> GopherEngine {
    let metrics = Arc::new(Metrics::new());
    let opts = StoreOptions {
        cache_slots: cache,
        disk: DiskModel::instant(),
        metrics: metrics.clone(),
        ..Default::default()
    };
    let stores = open_collection(dir, &opts).unwrap();
    let n = stores.len();
    GopherEngine::new(stores, ClusterSpec::new(n), metrics)
}

/// Acceptance: at the paper's s20-i20 layout, v2 attribute bodies must be
/// at least 1.5x smaller than v1 for the traceroute dataset, and the
/// deployment must be smaller on disk.
#[test]
fn v2_shrinks_traceroute_s20_i20_bodies_at_least_1_5x() {
    let gen = tr_gen(20);
    let (d1, r1) = deploy_version(&gen, "ratio-v1", 1, 20, 20, false);
    let (d2, r2) = deploy_version(&gen, "ratio-v2", 2, 20, 20, false);
    assert!(r1.attr_body_bytes > 0 && r2.attr_body_bytes > 0);
    let ratio = r1.attr_body_bytes as f64 / r2.attr_body_bytes as f64;
    assert!(
        ratio >= 1.5,
        "v2 body reduction only {ratio:.2}x (v1 {} vs v2 {})",
        r1.attr_body_bytes,
        r2.attr_body_bytes
    );
    assert!(
        r2.bytes_written < r1.bytes_written,
        "v2 on-disk {} not smaller than v1 {}",
        r2.bytes_written,
        r1.bytes_written
    );
    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}

/// Backward compatibility: a v1-format deployment (the wire fixture) must
/// read back exactly the generator's values through the new reader.
#[test]
fn v1_fixture_reads_back_generator_values() {
    let gen = tr_gen(8);
    let (dir, _) = deploy_version(&gen, "fixture-v1", 1, 3, 4, true);
    let opts = StoreOptions {
        cache_slots: 8,
        disk: DiskModel::instant(),
        metrics: Arc::new(Metrics::new()),
        ..Default::default()
    };
    let stores = open_collection(&dir, &opts).unwrap();
    let t = 3usize;
    let gi = gen.instance(t);
    let proj = goffish::gofs::Projection::all(
        &gen.template().vertex_schema,
        &gen.template().edge_schema,
    );
    let mut checked = 0usize;
    for store in &stores {
        for sg in store.subgraphs() {
            let sgi = store.read_instance(sg.id.local(), t, &proj).unwrap();
            for (local, &global) in sg.vertices.iter().enumerate() {
                let got = sgi.vertex_values(traceroute::vattr::RTT_MS, local as u32);
                let want = gi.vertex_values(gen.template(), traceroute::vattr::RTT_MS, global);
                assert_eq!(got.len(), want.len(), "rtt count v{global}");
                assert_eq!(got.first(), want.first(), "rtt first v{global}");
                if !got.is_empty() {
                    checked += 1;
                }
            }
            for (pos, &eidx) in sg.edges.iter().enumerate() {
                let got = sgi.edge_values(traceroute::eattr::LATENCY_MS, pos);
                let want = gi.edge_values(gen.template(), traceroute::eattr::LATENCY_MS, eidx);
                assert_eq!(got.len(), want.len(), "lat count e{eidx}");
                assert_eq!(got.first(), want.first(), "lat first e{eidx}");
            }
        }
    }
    assert!(checked > 10, "too few values checked ({checked})");
    std::fs::remove_dir_all(&dir).unwrap();
}

fn sssp_fp(dir: &PathBuf, prefetch: bool) -> Vec<(u64, usize, u64)> {
    let eng = make_engine(dir, 14);
    let gen = tr_gen(8);
    let src = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(src, traceroute::eattr::LATENCY_MS);
    eng.run(&app, &RunOptions { prefetch, ..Default::default() }).unwrap();
    let distances = app.results.distances.lock().unwrap();
    let mut fp: Vec<(u64, usize, u64)> = distances
        .iter()
        .flat_map(|(sgid, (t, d))| {
            d.iter()
                .enumerate()
                .map(move |(lv, &x)| (sgid.0, *t * 1_000_000 + lv, x.to_bits() as u64))
        })
        .collect();
    fp.sort_unstable();
    fp
}

fn pagerank_fp(dir: &PathBuf) -> Vec<(usize, u64, u64, Vec<(u64, u32)>)> {
    let eng = make_engine(dir, 14);
    let gen = tr_gen(8);
    let app = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    eng.run(&app, &RunOptions::default()).unwrap();
    let by_sg = app.results.by_subgraph.lock().unwrap();
    let mut fp: Vec<(usize, u64, u64, Vec<(u64, u32)>)> = by_sg
        .iter()
        .map(|((t, sgid), s)| {
            (
                *t,
                sgid.0,
                s.mass.to_bits(),
                s.top.iter().map(|&(v, r)| (v, r.to_bits())).collect(),
            )
        })
        .collect();
    fp.sort();
    fp
}

/// Acceptance: SSSP and PageRank outputs are bit-identical across v1/v2
/// slice formats and prefetch on/off.
#[test]
fn sssp_and_pagerank_outputs_bit_identical_across_formats_and_prefetch() {
    let gen = tr_gen(8);
    let (d1, _) = deploy_version(&gen, "apps-v1", 1, 4, 3, true);
    let (d2, _) = deploy_version(&gen, "apps-v2", 2, 4, 3, true);

    let s_v1_pf = sssp_fp(&d1, true);
    let s_v1_np = sssp_fp(&d1, false);
    let s_v2_pf = sssp_fp(&d2, true);
    let s_v2_np = sssp_fp(&d2, false);
    assert!(!s_v1_pf.is_empty());
    assert_eq!(s_v1_pf, s_v1_np, "prefetch changed SSSP outputs (v1)");
    assert_eq!(s_v2_pf, s_v2_np, "prefetch changed SSSP outputs (v2)");
    assert_eq!(s_v1_pf, s_v2_pf, "slice format changed SSSP outputs");

    let p_v1 = pagerank_fp(&d1);
    let p_v2 = pagerank_fp(&d2);
    assert!(!p_v1.is_empty());
    assert_eq!(p_v1, p_v2, "slice format changed PageRank outputs");

    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}
