//! Background group compaction (gofs::ingest::compact) and follow mode
//! for temporal pools: read-amortization wins, crash-window recovery,
//! and batch ≡ follow bit-equivalence over an ingested-then-compacted
//! collection (the PR acceptance suite).

use goffish::apps::{NHopApp, PageRankApp, SsspApp};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{
    compact_collection, deploy, deploy_template, open_collection, CollectionAppender,
    CompactOptions, DeployConfig, DiskModel, IngestOptions, Projection, StoreOptions,
};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use goffish::runtime::ScalarBackend;
use std::path::PathBuf;
use std::sync::Arc;

const PARTS: usize = 2;
const BINS: usize = 3;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gofs-compact-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tr_gen() -> TraceRouteGenerator {
    TraceRouteGenerator::new(TraceRouteParams::tiny())
}

fn opts(cache: usize) -> StoreOptions {
    StoreOptions {
        cache_slots: cache,
        disk: DiskModel::instant(),
        metrics: Arc::new(Metrics::new()),
        ..Default::default()
    }
}

fn engine(dir: &PathBuf, cache: usize) -> GopherEngine {
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions {
        cache_slots: cache,
        disk: DiskModel::instant(),
        metrics: metrics.clone(),
        ..Default::default()
    };
    GopherEngine::new(open_collection(dir, &o).unwrap(), ClusterSpec::new(PARTS), metrics)
}

/// Stream `gen`'s instances `[0, to)` through a fresh appender.
fn ingest_all(dir: &PathBuf, gen: &TraceRouteGenerator, to: usize, opts: IngestOptions) {
    let mut app = CollectionAppender::open(dir, opts).unwrap();
    for t in 0..to {
        assert_eq!(app.append(&gen.instance(t)).unwrap(), t);
    }
}

/// Every value of every instance must read back identically from the two
/// collections — grouping is a layout choice, never a semantic one.
fn assert_stores_identical(da: &PathBuf, db: &PathBuf, n_ts: usize) {
    let sa = open_collection(da, &opts(64)).unwrap();
    let sb = open_collection(db, &opts(64)).unwrap();
    assert_eq!(sa.len(), sb.len());
    for (a, b) in sa.iter().zip(&sb) {
        assert_eq!(a.n_instances(), n_ts);
        assert_eq!(b.n_instances(), n_ts);
        let proj = Projection::all(a.vertex_schema(), a.edge_schema());
        for sg in a.subgraphs() {
            for t in 0..n_ts {
                let ia = a.read_instance(sg.id.local(), t, &proj).unwrap();
                let ib = b.read_instance(sg.id.local(), t, &proj).unwrap();
                assert_eq!(ia.window, ib.window, "window t{t}");
                for attr in 0..a.vertex_schema().len() {
                    for v in 0..sg.n_vertices() as u32 {
                        assert_eq!(
                            ia.vertex_values(attr, v),
                            ib.vertex_values(attr, v),
                            "vattr {attr} v{v} t{t}"
                        );
                    }
                }
                for attr in 0..a.edge_schema().len() {
                    for e in 0..sg.edges.len() {
                        assert_eq!(
                            ia.edge_values(attr, e),
                            ib.edge_values(attr, e),
                            "eattr {attr} e{e} t{t}"
                        );
                    }
                }
            }
        }
    }
}

/// Full-projection scan of every (subgraph, timestep); returns total
/// slice reads (the read-amortization probe).
fn full_scan_reads(dir: &PathBuf) -> u64 {
    let stores = open_collection(dir, &opts(256)).unwrap();
    let mut reads = 0u64;
    for s in &stores {
        let proj = Projection::all(s.vertex_schema(), s.edge_schema());
        for t in 0..s.n_instances() {
            for sg in s.subgraphs() {
                let mut tr = goffish::gofs::ReadTrace::default();
                s.read_instance_traced(sg.id.local(), t, &proj, &mut tr).unwrap();
                reads += tr.slices_read;
            }
        }
    }
    reads
}

fn sssp_fingerprint(eng: &GopherEngine, gen: &TraceRouteGenerator, opts: &RunOptions) -> Vec<(u64, u32, i64)> {
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    eng.run(&app, opts).unwrap();
    let distances = app.results.distances.lock().unwrap();
    let mut out: Vec<(u64, u32, i64)> = distances
        .iter()
        .flat_map(|(sgid, (_, d))| {
            d.iter().enumerate().map(move |(lv, &x)| {
                let q = if x.is_finite() { (x as f64 * 1e6).round() as i64 } else { -1 };
                (sgid.0, lv as u32, q)
            })
        })
        .collect();
    out.sort_unstable();
    out
}

fn pagerank_fingerprint(eng: &GopherEngine, gen: &TraceRouteGenerator, opts: &RunOptions) -> Vec<(u64, i64)> {
    let app = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    let stats = eng.run(&app, opts).unwrap();
    assert!(!stats.per_timestep.is_empty());
    let mut out: Vec<(u64, i64)> = (0..3)
        .flat_map(|t| {
            app.results
                .top_k(t, 10)
                .into_iter()
                .map(move |(v, r)| (v, (r as f64 * 1e12).round() as i64))
        })
        .collect();
    out.sort_unstable();
    out
}

/// Tentpole acceptance (read amortization): compacting a small-`pack`
/// ingest shrinks the sealed-group count and the slice reads of a full
/// scan, while every value — and a sequential SSSP over the series —
/// stays bit-identical. A second pass is an idempotent no-op.
#[test]
fn compaction_reduces_groups_and_scan_reads_without_changing_values() {
    let gen = tr_gen();
    let n = gen.n_instances(); // 12
    let cfg = DeployConfig::new(PARTS, BINS, 1); // pack 1: one group per timestep
    let d_batch = tmpdir("amortize-batch");
    deploy(&gen, &cfg, &d_batch).unwrap();
    let d_feed = tmpdir("amortize-feed");
    deploy_template(&gen, &cfg, &d_feed).unwrap();
    ingest_all(&d_feed, &gen, n, IngestOptions::default());

    let reads_before = full_scan_reads(&d_feed);
    {
        let stores = open_collection(&d_feed, &opts(8)).unwrap();
        assert_eq!(stores[0].sealed_groups(), n, "pack-1 ingest: one group per timestep");
    }

    let report = compact_collection(&d_feed, &CompactOptions::new(4)).unwrap();
    assert_eq!(report.parts, PARTS);
    assert_eq!(report.groups_before, n * PARTS);
    assert_eq!(report.groups_after, (n / 4) * PARTS);
    assert_eq!(report.groups_merged, (n * PARTS) as u64);
    assert!(report.slices_deleted > 0);

    let reads_after = full_scan_reads(&d_feed);
    assert!(
        reads_after * 2 <= reads_before,
        "compaction should amortize reads: {reads_before} -> {reads_after}"
    );
    {
        let stores = open_collection(&d_feed, &opts(8)).unwrap();
        assert_eq!(stores[0].sealed_groups(), n / 4);
        assert_eq!(stores[0].n_instances(), n);
    }
    assert_stores_identical(&d_batch, &d_feed, n);
    let run = RunOptions::default();
    assert_eq!(
        sssp_fingerprint(&engine(&d_batch, 64), &gen, &run),
        sssp_fingerprint(&engine(&d_feed, 64), &gen, &run),
        "compaction changed SSSP outputs"
    );

    // Idempotent: a second pass finds nothing to merge and sweeps nothing.
    let again = compact_collection(&d_feed, &CompactOptions::new(4)).unwrap();
    assert_eq!(again.runs_merged, 0);
    assert_eq!(again.orphans_swept, 0);
    assert_eq!(again.groups_before, again.groups_after);
    std::fs::remove_dir_all(&d_batch).unwrap();
    std::fs::remove_dir_all(&d_feed).unwrap();
}

/// A `finish()`ed short tail group folds into the preceding groups.
#[test]
fn compaction_folds_finished_short_tail_group() {
    let gen = tr_gen();
    let n = 10usize; // pack 4 -> groups of 4, 4, 2 after finish()
    let cfg = DeployConfig::new(PARTS, BINS, 4);
    let d = tmpdir("tail-feed");
    deploy_template(&gen, &cfg, &d).unwrap();
    let mut app = CollectionAppender::open(&d, IngestOptions::default()).unwrap();
    for t in 0..n {
        app.append(&gen.instance(t)).unwrap();
    }
    let stats = app.finish().unwrap();
    assert_eq!(stats.sealed_groups, 3);

    let report = compact_collection(&d, &CompactOptions::new(10)).unwrap();
    assert_eq!(report.groups_after, PARTS, "4+4+2 folds into one group per partition");

    let gen10 = TraceRouteGenerator::new(TraceRouteParams {
        n_instances: n,
        ..TraceRouteParams::tiny()
    });
    let d_batch = tmpdir("tail-batch");
    deploy(&gen10, &cfg, &d_batch).unwrap();
    assert_stores_identical(&d_batch, &d, n);
    std::fs::remove_dir_all(&d).unwrap();
    std::fs::remove_dir_all(&d_batch).unwrap();
}

/// Crash-window acceptance: a crash at any point of a compaction pass —
/// mid multi-group re-pack, between the slice renames and the metadata
/// publish, or between the publish and the source-slice retirement —
/// leaves a collection that (a) reads correctly immediately and (b) is
/// fully repaired by simply re-running compaction.
#[test]
fn compaction_crash_windows_read_correctly_and_recover() {
    use goffish::gofs::ingest::compact::CrashPoint;
    let gen = tr_gen();
    let n = 8usize;
    let cfg = DeployConfig::new(PARTS, BINS, 1);
    let gen8 = TraceRouteGenerator::new(TraceRouteParams {
        n_instances: n,
        ..TraceRouteParams::tiny()
    });
    let d_batch = tmpdir("crash-batch");
    deploy(&gen8, &cfg, &d_batch).unwrap();

    for (tag, crash) in [
        ("midrepack", CrashPoint::MidRepack),
        ("prepublish", CrashPoint::BeforePublish),
        ("precleanup", CrashPoint::BeforeCleanup),
    ] {
        let d = tmpdir(&format!("crash-{tag}"));
        deploy_template(&gen, &cfg, &d).unwrap();
        ingest_all(&d, &gen, n, IngestOptions::default());

        // Target 3 over 8 pack-1 groups -> multiple planned runs, so
        // MidRepack really does stop between runs.
        let crashing = CompactOptions { crash, ..CompactOptions::new(3) };
        let err = compact_collection(&d, &crashing).unwrap_err();
        assert!(format!("{err:#}").contains("simulated crash"), "{err:#}");

        // (a) The collection still reads correctly, whichever side of
        // the publish the crash landed on.
        assert_stores_identical(&d_batch, &d, n);

        // (b) Re-running compaction completes the pass and sweeps any
        // orphans; the result is fully compacted and still identical.
        let report = compact_collection(&d, &CompactOptions::new(3)).unwrap();
        if crash == CrashPoint::BeforeCleanup {
            // Part 0 published before the "crash", so its retired source
            // slices became orphans for the re-run's sweep. (MidRepack /
            // BeforePublish orphans are the unpublished *new* slices.)
            assert!(report.orphans_swept > 0, "{tag}: sweep found nothing");
        }
        let stores = open_collection(&d, &opts(8)).unwrap();
        assert_eq!(stores[0].sealed_groups(), 3, "{tag}: 8 groups -> 3+3+2");
        assert_stores_identical(&d_batch, &d, n);
        let run = RunOptions::default();
        assert_eq!(
            sssp_fingerprint(&engine(&d_batch, 64), &gen8, &run),
            sssp_fingerprint(&engine(&d, 64), &gen8, &run),
            "{tag}: SSSP diverged after crash recovery"
        );
        std::fs::remove_dir_all(&d).unwrap();
    }
    std::fs::remove_dir_all(&d_batch).unwrap();
}

/// Tentpole acceptance (pool follow): an Independent and an
/// EventuallyDependent follow run over a live-ingested collection —
/// with inline compaction re-packing groups *while the Independent run
/// is reading them* — produce outputs bit-identical to batch runs over
/// a one-shot deployment of the same series.
#[test]
fn pool_follow_over_live_compacted_ingest_matches_batch() {
    let gen = tr_gen();
    let n = gen.n_instances();
    let cfg = DeployConfig::new(PARTS, BINS, 2);
    let d_batch = tmpdir("pf-batch");
    deploy(&gen, &cfg, &d_batch).unwrap();
    let d_feed = tmpdir("pf-feed");
    deploy_template(&gen, &cfg, &d_feed).unwrap();

    // Independent (PageRank) follow run, concurrent with the feeder.
    // compact_after(2): every 2 seals (4 timesteps) re-pack inline, so
    // the run's refresh + vanished-slice retry race a real compactor.
    let feed_dir = d_feed.clone();
    let feeder = std::thread::spawn(move || {
        let gen = tr_gen();
        std::thread::sleep(std::time::Duration::from_millis(80));
        let mut app = CollectionAppender::open(
            &feed_dir,
            IngestOptions::default().compact_after(2),
        )
        .unwrap();
        for t in 0..gen.n_instances() {
            app.append(&gen.instance(t)).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        app.stats()
    });
    let follow = RunOptions {
        follow: true,
        follow_poll_ms: 5,
        follow_idle_polls: 400, // ~2s of slack over the feed cadence
        temporal_workers: 3,
        ..Default::default()
    };
    let follow_pr = pagerank_fingerprint(&engine(&d_feed, 64), &gen, &follow);
    let feeder_stats = feeder.join().unwrap();
    assert!(feeder_stats.compactions > 0, "inline compaction never ran");
    let batch_pr = pagerank_fingerprint(&engine(&d_batch, 64), &gen, &RunOptions::default());
    assert_eq!(follow_pr, batch_pr, "follow PageRank diverged from batch");

    // The collection is now compacted; the timeline must still carry
    // every timestep.
    let stores = open_collection(&d_feed, &opts(8)).unwrap();
    assert_eq!(stores[0].n_instances(), n);
    assert!(
        stores[0].sealed_groups() < n / 2,
        "inline compaction should have merged pack-2 groups"
    );
    drop(stores);

    // EventuallyDependent (NHop) follow run over the ingested-then-
    // compacted collection: merge result identical to a batch run over
    // the one-shot deployment.
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let nhop_total = |dir: &PathBuf, run: &RunOptions| {
        let eng = engine(dir, 64);
        let mut app = NHopApp::new(source, 4, traceroute::eattr::LATENCY_MS);
        app.hist_hi = 2000.0;
        let stats = eng.run(&app, run).unwrap();
        assert_eq!(stats.per_timestep.len(), n);
        let composite = app.results.composite.lock().unwrap();
        composite.as_ref().unwrap().total()
    };
    let follow_ed = RunOptions {
        follow: true,
        follow_poll_ms: 2,
        follow_idle_polls: 5,
        temporal_workers: 3,
        ..Default::default()
    };
    assert_eq!(
        nhop_total(&d_feed, &follow_ed),
        nhop_total(&d_batch, &RunOptions::default()),
        "follow NHop merge diverged from batch"
    );
    std::fs::remove_dir_all(&d_batch).unwrap();
    std::fs::remove_dir_all(&d_feed).unwrap();
}

// ================= repartition crash windows (PR 10) =================
//
// The drift re-partition pass (`gofs::ingest::repartition`) rebuilds
// every partition and swaps the rebuild in publish-last. Each injected
// crash window must leave the collection either fully old (commit
// marker never written) or fully new (marker written → recovery rolls
// the swap forward) — and in both cases the canonical analytics output,
// keyed by external vertex id, must not move a bit.

use goffish::gofs::ingest::repartition::{load_traffic, recover, write_traffic};
use goffish::gofs::{repartition_collection, RepartCrash, RepartitionOptions};
use goffish::metrics::keys as mkeys;
use goffish::partition::PartitionStrategy;

/// Final SSSP distances keyed (ext id → f32 bits): placement-invariant.
fn sssp_ext_canonical(dir: &PathBuf) -> Vec<(u64, u32)> {
    let eng = engine(dir, 32);
    let gen = tr_gen();
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    eng.run(&app, &RunOptions::default()).unwrap();
    let distances = app.results.distances.lock().unwrap();
    let mut out: Vec<(u64, u32)> = Vec::new();
    for s in eng.stores() {
        for sg in s.subgraphs() {
            if let Some((_, d)) = distances.get(&sg.id) {
                for (lv, &x) in d.iter().enumerate() {
                    out.push((sg.ext_ids[lv], x.to_bits()));
                }
            }
        }
    }
    out.sort_unstable();
    out
}

fn deployed_tr(tag: &str) -> PathBuf {
    let dir = tmpdir(tag);
    deploy(&tr_gen(), &DeployConfig::new(PARTS, BINS, 3), &dir).unwrap();
    dir
}

fn repart_opts(crash: RepartCrash) -> RepartitionOptions {
    RepartitionOptions {
        strategy: Some(PartitionStrategy::Fennel),
        crash,
        ..Default::default()
    }
}

/// Clean pass: vertices move, the store reopens, outputs hold, and the
/// `partition.edge_cut_pct` metric + `repartition` event are recorded.
#[test]
fn repartition_clean_pass_preserves_outputs() {
    let dir = deployed_tr("repart-clean");
    let before = sssp_ext_canonical(&dir);
    assert!(!before.is_empty());
    let metrics = Arc::new(Metrics::new());
    let rep = repartition_collection(
        &dir,
        &RepartitionOptions { metrics: metrics.clone(), ..repart_opts(RepartCrash::None) },
    )
    .unwrap();
    assert!(rep.moved_vertices > 0, "fennel re-placement moved nothing");
    assert!(metrics.get(mkeys::PARTITION_EDGE_CUT_BP) > 0, "edge-cut metric not recorded");
    // No residue: staging, retired copies and the marker are all gone.
    for residue in [".repart", ".repart.old", ".repart.commit"] {
        assert!(!dir.join(residue).exists(), "{residue} left behind");
    }
    assert_eq!(sssp_ext_canonical(&dir), before, "re-partition changed SSSP");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Crash before the commit marker: the live store was never touched.
/// Recovery sweeps the staging directory and the original layout (and
/// outputs) remain; a re-run of the pass then completes normally.
#[test]
fn repartition_crash_before_commit_leaves_old_layout() {
    let dir = deployed_tr("repart-precommit");
    let before = sssp_ext_canonical(&dir);
    let err = repartition_collection(&dir, &repart_opts(RepartCrash::BeforeCommit));
    assert!(err.is_err(), "injected crash did not surface");
    assert!(dir.join(".repart").exists(), "crash window left no staging");
    assert!(!dir.join(".repart.commit").exists(), "marker must not precede the swap");

    assert!(recover(&dir).unwrap(), "recovery had nothing to do");
    assert!(!dir.join(".repart").exists());
    assert_eq!(sssp_ext_canonical(&dir), before, "uncommitted pass changed outputs");

    // A subsequent pass (which also recovers on entry) completes
    // normally and still preserves outputs.
    let rep = repartition_collection(&dir, &repart_opts(RepartCrash::None)).unwrap();
    assert!(rep.moved_vertices > 0);
    assert_eq!(sssp_ext_canonical(&dir), before);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Crash mid-swap, after the commit marker: some partitions are new,
/// the rest still staged. Recovery must roll the swap forward to the
/// new layout — outputs identical, no residue.
#[test]
fn repartition_crash_mid_swap_rolls_forward() {
    let dir = deployed_tr("repart-midswap");
    let before = sssp_ext_canonical(&dir);
    let err = repartition_collection(&dir, &repart_opts(RepartCrash::MidSwap));
    assert!(err.is_err(), "injected crash did not surface");
    assert!(dir.join(".repart.commit").exists(), "mid-swap crash must leave the marker");

    assert!(recover(&dir).unwrap(), "recovery had nothing to do");
    for residue in [".repart", ".repart.old", ".repart.commit"] {
        assert!(!dir.join(residue).exists(), "{residue} left behind after roll-forward");
    }
    assert_eq!(sssp_ext_canonical(&dir), before, "rolled-forward swap changed outputs");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Crash after the swap but before cleanup: the new layout is fully
/// live; recovery just clears the retired copies and the marker. The
/// recovery hook on the compaction path (`compact_collection` calls it
/// under the writer lock) is exercised instead of calling recover
/// directly.
#[test]
fn repartition_crash_before_cleanup_heals_via_compact() {
    let dir = deployed_tr("repart-precleanup");
    let before = sssp_ext_canonical(&dir);
    let err = repartition_collection(&dir, &repart_opts(RepartCrash::BeforeCleanup));
    assert!(err.is_err(), "injected crash did not surface");
    assert!(dir.join(".repart.old").exists(), "cleanup crash must leave retired copies");
    assert!(dir.join(".repart.commit").exists());

    // Any writer-lock entry point recovers; compaction is one of them.
    compact_collection(&dir, &CompactOptions::default()).unwrap();
    for residue in [".repart", ".repart.old", ".repart.commit"] {
        assert!(!dir.join(residue).exists(), "{residue} survived the recovery hook");
    }
    assert_eq!(sssp_ext_canonical(&dir), before, "healed swap changed outputs");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The traffic side-channel round-trips: what `run --traffic-out`
/// writes, `compact --repartition --traffic` reads back — including
/// comment lines and duplicate-pair accumulation.
#[test]
fn traffic_file_round_trips() {
    let dir = tmpdir("traffic");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("traffic.txt");
    let pairs = vec![((0usize, 1usize), (120u64, 48_000u64)), ((1, 0), (7, 512))];
    write_traffic(&path, &pairs).unwrap();
    assert_eq!(load_traffic(&path).unwrap(), pairs);

    // Duplicated pairs accumulate; blank and comment lines are skipped.
    std::fs::write(&path, "# header\n\n0 1 10 100\n0 1 5 50\n2 0 1 9\n").unwrap();
    assert_eq!(
        load_traffic(&path).unwrap(),
        vec![((0, 1), (15, 150)), ((2, 0), (1, 9))]
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
