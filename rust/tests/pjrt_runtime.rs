//! Integration: AOT artifacts load, compile and match the scalar oracles.
//!
//! Requires AOT artifacts (`python/compile/aot.py` writes
//! `artifacts/manifest.txt` + per-kernel HLO files). When they have not
//! been generated — the common case on machines without the Python
//! toolchain — these tests SKIP (pass vacuously with a note on stderr)
//! rather than failing `cargo test` for an optional backend.

use goffish::graph::{Schema, TemplateBuilder};
use goffish::metrics::Metrics;
use goffish::partition::{extract_partitions, Partitioning, Subgraph};
use goffish::runtime::pjrt::{PjrtBackend, PjrtEngine, BIG};
use goffish::runtime::{LocalSpmv, MinPlus, ScalarBackend};
use goffish::util::Prng;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts_dir() -> PathBuf {
    std::env::var("GOFFISH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Cheap skip check: have the AOT artifacts been generated?
fn artifacts_present() -> bool {
    let dir = artifacts_dir();
    let present = dir.join("manifest.txt").exists();
    if !present {
        eprintln!(
            "skipping PJRT test: no artifacts at {} (generate with python/compile/aot.py)",
            dir.display()
        );
    }
    present
}

/// `None` (skip) when the artifacts are absent; panic on any *other*
/// load failure — a present-but-broken artifacts dir is a real bug.
fn engine(prefer_b: Option<usize>) -> Option<Arc<PjrtEngine>> {
    if !artifacts_present() {
        return None;
    }
    Some(
        PjrtEngine::load(&artifacts_dir(), prefer_b, Arc::new(Metrics::new()))
            .expect("artifacts present but failed to load"),
    )
}

/// A random connected-ish subgraph with `n` vertices and ~3n edges.
fn random_subgraph(n: usize, seed: u64) -> Subgraph {
    let mut rng = Prng::new(seed);
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    for i in 0..n {
        b.vertex(i as u64);
    }
    // Spanning chain keeps it one subgraph.
    for i in 0..n - 1 {
        b.edge(i as u32, i as u32 + 1);
    }
    for _ in 0..3 * n {
        let s = rng.gen_range(n as u64) as u32;
        let d = rng.gen_range(n as u64) as u32;
        b.edge(s, d);
    }
    let t = b.build();
    let p = Partitioning { n_parts: 1, assign: vec![0; n] };
    extract_partitions(&t, &p).remove(0).subgraphs.remove(0)
}

#[test]
fn pjrt_kernels_match_scalar_backends() {
    let Some(eng) = engine(Some(32)) else { return };
    let mut backend = PjrtBackend::new(eng);
    backend.min_vertices = 0; // force the PJRT path even for small graphs
    backend.force_tiles = true; // bypass the density guard: we WANT the tile path
    let scalar = ScalarBackend;

    for (n, seed) in [(50usize, 1u64), (130, 2), (300, 3)] {
        let sg = random_subgraph(n, seed);
        let mut rng = Prng::new(seed ^ 0xFF);
        // --- SpMV ---
        let active: Vec<bool> =
            (0..sg.n_local_edges()).map(|_| rng.gen_bool(0.7)).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.gen_f64() as f32).collect();
        let op_p = LocalSpmv::prepare(&backend, &sg, &active);
        let op_s = LocalSpmv::prepare(&scalar, &sg, &active);
        let mut y_p = vec![0.0f32; n];
        let mut y_s = vec![0.0f32; n];
        op_p.apply(&x, &mut y_p);
        op_s.apply(&x, &mut y_s);
        for v in 0..n {
            assert!(
                (y_p[v] - y_s[v]).abs() <= 1e-4 * (1.0 + y_s[v].abs()),
                "n={n} spmv mismatch at {v}: pjrt={} scalar={}",
                y_p[v],
                y_s[v]
            );
        }

        // --- MinPlus ---
        let weights: Vec<f32> = (0..sg.n_local_edges())
            .map(|_| if rng.gen_bool(0.8) { 1.0 + rng.gen_f64() as f32 * 9.0 } else { f32::INFINITY })
            .collect();
        let mp_p = MinPlus::prepare(&backend, &sg, &weights);
        let mp_s = MinPlus::prepare(&scalar, &sg, &weights);
        let mut d_p = vec![f32::INFINITY; n];
        let mut d_s = vec![f32::INFINITY; n];
        d_p[0] = 0.0;
        d_s[0] = 0.0;
        while mp_p.relax(&mut d_p) {}
        while mp_s.relax(&mut d_s) {}
        for v in 0..n {
            let (a, b) = (d_p[v], d_s[v]);
            let a = if a >= BIG * 0.5 { f32::INFINITY } else { a };
            match (a.is_finite(), b.is_finite()) {
                (true, true) => assert!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "n={n} minplus mismatch at {v}: pjrt={a} scalar={b}"
                ),
                (fa, fb) => assert_eq!(fa, fb, "n={n} reachability mismatch at {v}: {a} vs {b}"),
            }
        }
    }
}

#[test]
fn pjrt_engine_reports_kernel_metrics() {
    if !artifacts_present() {
        return;
    }
    let metrics = Arc::new(Metrics::new());
    let eng = PjrtEngine::load(&artifacts_dir(), Some(32), metrics.clone()).unwrap();
    let k = eng.k;
    let b = eng.b;
    let a = vec![0.0f32; k * b * b];
    let x = vec![1.0f32; k * b];
    let out = eng
        .execute(&format!("pagerank_b{b}_k{k}"), vec![(a, vec![k, b, b]), (x, vec![k, b])])
        .unwrap();
    assert_eq!(out.len(), k * b);
    assert!(out.iter().all(|&v| v == 0.0));
    assert_eq!(metrics.get(goffish::metrics::keys::KERNEL_CALLS), 1);
    assert!(metrics.get(goffish::metrics::keys::KERNEL_NS) > 0);
}

#[test]
fn pjrt_variant_selection() {
    let Some(eng) = engine(None) else { return }; // largest available
    assert!(eng.b >= 64, "expected a large-block variant, got b={}", eng.b);
    let eng32 = engine(Some(32)).unwrap();
    assert_eq!(eng32.b, 32);
    assert!(eng32.specs().iter().any(|s| s.name == "minplus"));
}

#[test]
fn unknown_kernel_is_a_clean_error() {
    let Some(eng) = engine(Some(32)) else { return };
    let err = eng.execute("nope_b32_k4", vec![]).unwrap_err().to_string();
    assert!(err.contains("unknown kernel"), "{err}");
}
