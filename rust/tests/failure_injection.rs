//! Failure injection: corrupted/missing slices, malformed messages, and
//! engine error paths must surface as clean errors, never wrong answers.

use goffish::cluster::ClusterSpec;
use goffish::datagen::{TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{
    deploy, open_collection, DeployConfig, DiskModel, Projection, SliceFile, Store, StoreOptions,
};
use goffish::gopher::{
    Application, ComputeCtx, GopherEngine, Pattern, Payload, RunOptions, SubgraphProgram,
};
use goffish::graph::{Schema, SubgraphId};
use goffish::metrics::Metrics;
use goffish::partition::Subgraph;
use std::path::PathBuf;
use std::sync::Arc;

fn deployed(tag: &str) -> PathBuf {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = std::env::temp_dir().join(format!("goffish-fi-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    deploy(&gen, &DeployConfig::new(2, 3, 4), &dir).unwrap();
    dir
}

fn opts() -> StoreOptions {
    StoreOptions { cache_slots: 8, disk: DiskModel::instant(), metrics: Arc::new(Metrics::new()), ..Default::default() }
}

/// Find some attribute slice file in a partition dir.
fn find_attr_slice(dir: &PathBuf) -> PathBuf {
    let mut stack = vec![dir.join("part-0/attr")];
    while let Some(d) = stack.pop() {
        for e in std::fs::read_dir(&d).unwrap() {
            let p = e.unwrap().path();
            if p.is_dir() {
                stack.push(p);
            } else {
                return p;
            }
        }
    }
    panic!("no attribute slices found");
}

#[test]
fn corrupted_attribute_slice_is_detected() {
    let dir = deployed("corrupt-attr");
    let victim = find_attr_slice(&dir);
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&victim, &bytes).unwrap();

    let store = Store::open(&dir, 0, opts()).unwrap();
    let proj = Projection::all(store.vertex_schema(), store.edge_schema());
    // Some read must fail with a CRC/deflate error; none may return junk.
    let mut saw_error = false;
    for sg in store.subgraphs() {
        for t in 0..store.n_instances() {
            if let Err(e) = store.read_instance(sg.id.local(), t, &proj) {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("CRC") || msg.contains("deflate") || msg.contains("truncated"),
                    "unexpected error: {msg}"
                );
                saw_error = true;
            }
        }
    }
    assert!(saw_error, "corruption went undetected");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn truncated_template_slice_fails_to_open() {
    let dir = deployed("trunc-template");
    let t = dir.join("part-1/template.slice");
    let bytes = std::fs::read(&t).unwrap();
    std::fs::write(&t, &bytes[..bytes.len() / 3]).unwrap();
    assert!(Store::open(&dir, 1, opts()).is_err());
    // Other partitions still open fine.
    assert!(Store::open(&dir, 0, opts()).is_ok());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn missing_collection_meta_is_a_clean_error() {
    let dir = std::env::temp_dir().join(format!("goffish-fi-nometa-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let err = match open_collection(&dir, &opts()) {
        Err(e) => e,
        Ok(_) => panic!("opened a non-collection"),
    };
    assert!(format!("{err:#}").contains("collection"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn wrong_partition_id_rejected() {
    let dir = deployed("swap");
    // Copy part-1's template over part-0's: ids won't match the directory.
    std::fs::copy(dir.join("part-1/template.slice"), dir.join("part-0/template.slice")).unwrap();
    let err = match Store::open(&dir, 0, opts()) {
        Err(e) => e,
        Ok(_) => panic!("opened a mismatched partition"),
    };
    assert!(format!("{err:#}").contains("mismatch"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn slice_kind_confusion_rejected() {
    let dir = deployed("kind");
    // Overwrite an attribute slice with a metadata-kind slice.
    let victim = find_attr_slice(&dir);
    SliceFile::new(goffish::gofs::SliceKind::Metadata, b"not an attr".to_vec())
        .write_to(&victim, false)
        .unwrap();
    let store = Store::open(&dir, 0, opts()).unwrap();
    let proj = Projection::all(store.vertex_schema(), store.edge_schema());
    let mut saw_error = false;
    for sg in store.subgraphs() {
        if store.read_instance(sg.id.local(), 0, &proj).is_err() {
            saw_error = true;
        }
    }
    // Either this partition owned the victim (error) or part-1 did (skip).
    let _ = saw_error;
    std::fs::remove_dir_all(&dir).unwrap();
}

/// App that sends to a nonexistent subgraph: the engine must error out,
/// not deadlock or misroute.
struct BadRouteApp;
struct BadRouteProgram;
impl SubgraphProgram for BadRouteProgram {
    fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &goffish::gofs::SubgraphInstance, _msgs: &[Payload]) {
        ctx.send_to_subgraph(SubgraphId::new(777, 777), vec![1, 2, 3]);
        ctx.vote_to_halt();
    }
}
impl Application for BadRouteApp {
    fn name(&self) -> &str {
        "bad-route"
    }
    fn pattern(&self) -> Pattern {
        Pattern::Sequential
    }
    fn projection(&self, _: &Schema, _: &Schema) -> Projection {
        Projection::none()
    }
    fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
        Box::new(BadRouteProgram)
    }
}

#[test]
fn message_to_unknown_subgraph_is_an_error() {
    let dir = deployed("badroute");
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions { cache_slots: 8, disk: DiskModel::instant(), metrics: metrics.clone(), ..Default::default() };
    let stores = open_collection(&dir, &o).unwrap();
    let eng = GopherEngine::new(stores, ClusterSpec::new(2), metrics);
    let err = eng
        .run(&BadRouteApp, &RunOptions { timesteps: Some(vec![0]), ..Default::default() })
        .unwrap_err();
    assert!(format!("{err:#}").contains("unknown subgraph"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// App whose messages are garbage bytes: real apps must tolerate decode
/// failures gracefully (SSSP ignores undecodable payloads).
#[test]
fn sssp_tolerates_garbage_messages() {
    // Direct check on the decode path: a malformed pairs list must not
    // panic MsgReader users.
    use goffish::gopher::MsgReader;
    let garbage = vec![0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF];
    let mut r = MsgReader::new(&garbage);
    assert!(r.pairs_u32_f64().is_err());
}

/// A BSP that never halts must hit the superstep bound, not spin forever.
struct SpinApp;
struct SpinProgram;
impl SubgraphProgram for SpinProgram {
    fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &goffish::gofs::SubgraphInstance, _msgs: &[Payload]) {
        // never votes to halt
        let _ = ctx.superstep;
    }
}
impl Application for SpinApp {
    fn name(&self) -> &str {
        "spin"
    }
    fn pattern(&self) -> Pattern {
        Pattern::Sequential
    }
    fn projection(&self, _: &Schema, _: &Schema) -> Projection {
        Projection::none()
    }
    fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
        Box::new(SpinProgram)
    }
}

#[test]
fn runaway_bsp_hits_superstep_bound() {
    let dir = deployed("spin");
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions { cache_slots: 8, disk: DiskModel::instant(), metrics: metrics.clone(), ..Default::default() };
    let stores = open_collection(&dir, &o).unwrap();
    let eng = GopherEngine::new(stores, ClusterSpec::new(2), metrics);
    let err = eng
        .run(
            &SpinApp,
            &RunOptions { timesteps: Some(vec![0]), max_supersteps: 25, ..Default::default() },
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("did not converge"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn out_of_range_timestep_is_an_error() {
    let dir = deployed("range");
    let store = Store::open(&dir, 0, opts()).unwrap();
    let proj = Projection::none();
    assert!(store.read_instance(0, 999, &proj).is_err());
    assert!(store.read_instance(usize::MAX / 2, 0, &proj).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}
