//! Partition-quality regression suite (the PR 10 gate).
//!
//! Pins the contracts every streaming placement strategy must satisfy —
//! totality, balance, determinism — and the reason the graph-aware
//! strategies exist at all: on a planted-cluster graph their edge cut
//! must come in strictly below the count-only `binpack` baseline, at
//! both 2 and 4 partitions. Plus the connected-component extraction
//! edge cases (`partition/subgraph.rs`) the main property test doesn't
//! reach: empty partitions, all-isolated vertices, and one giant
//! component flowing through bin packing.

use goffish::graph::{GraphTemplate, Schema, TemplateBuilder, VIdx};
use goffish::partition::{
    binpack_subgraphs, extract_partitions, partition_graph, stream_place, CountPlacer,
    FennelPlacer, PartitionOptions, PartitionStrategy, Partitioning,
};
use goffish::util::propcheck::forall;

const STRATEGIES: [PartitionStrategy; 3] =
    [PartitionStrategy::Ldg, PartitionStrategy::Fennel, PartitionStrategy::Binpack];

fn opts(k: usize, strategy: PartitionStrategy) -> PartitionOptions {
    PartitionOptions { strategy, ..PartitionOptions::new(k) }
}

/// `clusters` dense communities of `csize` vertices (ring + skip-7
/// chords) with exactly one weak edge between consecutive clusters — the
/// planted structure a graph-aware placer should recover and a
/// count-only placer shreds.
fn planted_clusters(clusters: usize, csize: usize) -> GraphTemplate {
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    let n = (clusters * csize) as u32;
    for i in 0..clusters * csize {
        b.vertex(i as u64);
    }
    for c in 0..clusters {
        let base = (c * csize) as u32;
        for i in 0..csize as u32 {
            b.edge(base + i, base + (i + 1) % csize as u32);
            b.edge(base + i, base + (i + 7) % csize as u32);
        }
        b.edge(base, (base + csize as u32) % n);
    }
    b.build()
}

fn random_template(g: &mut goffish::util::propcheck::Gen, n_max: usize) -> GraphTemplate {
    let n = g.usize(1..n_max);
    let m = g.usize(0..n_max * 3);
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    for i in 0..n {
        b.vertex(i as u64);
    }
    for _ in 0..m {
        b.edge(g.usize(0..n) as u32, g.usize(0..n) as u32);
    }
    b.build()
}

// ---------------------------------------------------------------- tentpole

/// The headline gate: fennel's cut strictly below binpack's on the
/// planted-cluster graph, at k=2 and k=4 — and never at the cost of
/// correctness (totality + balance hold for both).
#[test]
fn fennel_cut_strictly_below_binpack_on_planted_clusters() {
    let t = planted_clusters(8, 48);
    for k in [2usize, 4] {
        let fennel = partition_graph(&t, &opts(k, PartitionStrategy::Fennel));
        let binpack = partition_graph(&t, &opts(k, PartitionStrategy::Binpack));
        let (cf, cb) = (fennel.edge_cut_pct(&t), binpack.edge_cut_pct(&t));
        assert!(
            cf < cb,
            "k={k}: fennel cut {cf:.2}% not strictly below binpack {cb:.2}%"
        );
        // The win must be structural, not marginal: the baseline shreds
        // clusters (most edges cut) while fennel keeps the large majority
        // of edges internal. At k=2 the clusters are recovered almost
        // whole; at k=4 the tighter capacity (~2.1 clusters/part) forces
        // some splits, so the bound is looser there.
        assert!(cb > 50.0, "k={k}: binpack cut {cb:.2}% — baseline suspiciously good");
        assert!(cf < cb / 2.0, "k={k}: fennel cut {cf:.2}% not well below binpack {cb:.2}%");
        if k == 2 {
            assert!(cf < 10.0, "k=2: fennel cut {cf:.2}% — clusters not recovered");
        }
    }
}

/// LDG (the default) must also beat the graph-oblivious baseline.
#[test]
fn ldg_cut_strictly_below_binpack_on_planted_clusters() {
    let t = planted_clusters(8, 48);
    for k in [2usize, 4] {
        let ldg = partition_graph(&t, &opts(k, PartitionStrategy::Ldg));
        let binpack = partition_graph(&t, &opts(k, PartitionStrategy::Binpack));
        assert!(
            ldg.edge_cut_pct(&t) < binpack.edge_cut_pct(&t),
            "k={k}: ldg {:.2}% vs binpack {:.2}%",
            ldg.edge_cut_pct(&t),
            binpack.edge_cut_pct(&t)
        );
    }
}

// ---------------------------------------------------------- property tests

/// Every strategy is total: each vertex placed exactly once, in a valid
/// partition, and the per-partition sizes account for all of them.
#[test]
fn every_vertex_placed_exactly_once() {
    forall(20, |g| {
        let t = random_template(g, 60);
        let k = g.usize(1..6);
        for s in STRATEGIES {
            let p = partition_graph(&t, &opts(k, s));
            assert_eq!(p.assign.len(), t.n_vertices(), "{}", s.name());
            assert!(
                p.assign.iter().all(|&x| (x as usize) < k),
                "{}: out-of-range partition id",
                s.name()
            );
            assert_eq!(
                p.sizes().iter().sum::<usize>(),
                t.n_vertices(),
                "{}: sizes don't sum to n",
                s.name()
            );
        }
    });
}

/// No strategy ever exceeds the balance contract: every partition holds
/// at most ceil((1+slack)·n/k) vertices, streaming pass and refinement
/// sweeps included.
#[test]
fn balance_slack_never_exceeded() {
    forall(20, |g| {
        let t = random_template(g, 80);
        let k = g.usize(2..6);
        for s in STRATEGIES {
            let o = opts(k, s);
            let p = partition_graph(&t, &o);
            let cap =
                ((t.n_vertices() as f64) * (1.0 + o.slack) / k as f64).ceil() as usize;
            let max = p.sizes().into_iter().max().unwrap_or(0);
            assert!(
                max <= cap,
                "{}: partition of {max} vertices exceeds cap {cap} (n={}, k={k})",
                s.name(),
                t.n_vertices()
            );
        }
    });
}

/// Placement is a pure function of (input order, seed) for every
/// strategy — the property that makes deployments reproducible.
#[test]
fn deterministic_for_fixed_order_and_seed() {
    forall(10, |g| {
        let t = random_template(g, 60);
        let k = g.usize(2..5);
        let seed = g.usize(0..1 << 30) as u64;
        for s in STRATEGIES {
            let o = PartitionOptions { seed, ..opts(k, s) };
            assert_eq!(
                partition_graph(&t, &o),
                partition_graph(&t, &o),
                "{}: same seed, different placement",
                s.name()
            );
        }
    });
}

/// The shared streaming loop drives a raw placer over an explicit order:
/// the result assigns every streamed vertex and reruns identically.
#[test]
fn stream_place_assigns_all_and_replays() {
    let t = planted_clusters(4, 16);
    let undirected = {
        // Re-derive the undirected adjacency the partitioner scores with.
        let mut edges = Vec::new();
        for e in 0..t.n_edges() {
            let (s, d) = (t.edge_src[e], t.edge_dst[e]);
            if s != d {
                edges.push((s, d, e as u32));
                edges.push((d, s, e as u32));
            }
        }
        goffish::graph::Csr::from_edges(t.n_vertices(), &edges)
    };
    let order: Vec<VIdx> = (0..t.n_vertices() as VIdx).rev().collect();
    let run = |seed: u64| {
        let mut placer = FennelPlacer::new(t.n_vertices(), t.n_edges(), 3, 0.05, seed);
        stream_place(&undirected, &order, 3, &mut placer)
    };
    let a = run(7);
    assert!(a.iter().all(|&p| p < 3), "unplaced or out-of-range vertex");
    assert_eq!(a, run(7), "same placer construction, different stream result");

    let mut count = CountPlacer;
    let c = stream_place(&undirected, &order, 3, &mut count);
    let mut sizes = [0usize; 3];
    for &p in &c {
        sizes[p as usize] += 1;
    }
    // Count-only placement is perfectly level (ties to the lowest index).
    assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1, "{sizes:?}");
}

// ------------------------------------------------------------- edge cases

#[test]
fn empty_graph_all_strategies() {
    let t = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![])).build();
    for s in STRATEGIES {
        let p = partition_graph(&t, &opts(3, s));
        assert_eq!(p.assign.len(), 0, "{}", s.name());
        assert_eq!(p.cut_edges(&t), 0);
        assert_eq!(p.edge_cut_pct(&t), 0.0);
    }
}

#[test]
fn singleton_graph_all_strategies() {
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    b.vertex(42);
    let t = b.build();
    for s in STRATEGIES {
        let p = partition_graph(&t, &opts(4, s));
        assert_eq!(p.assign.len(), 1, "{}", s.name());
        assert!(p.assign[0] < 4);
        assert_eq!(p.cut_edges(&t), 0);
    }
}

/// A star is the worst case for neighbor affinity (every leaf's only
/// neighbor is the hub): placement must still be total and balanced.
#[test]
fn star_graph_all_strategies() {
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    let n = 41usize; // hub + 40 leaves
    for i in 0..n {
        b.vertex(i as u64);
    }
    for leaf in 1..n as u32 {
        b.edge(0, leaf);
        b.edge(leaf, 0);
    }
    let t = b.build();
    for s in STRATEGIES {
        let o = opts(4, s);
        let p = partition_graph(&t, &o);
        let cap = ((n as f64) * 1.05 / 4.0).ceil() as usize;
        assert!(
            p.sizes().into_iter().max().unwrap() <= cap,
            "{}: star overfills a partition",
            s.name()
        );
    }
}

/// A clique cannot be cut well — but the balance contract still wins
/// over affinity: no strategy may pile the whole clique on one host.
#[test]
fn clique_graph_all_strategies() {
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    let n = 24usize;
    for i in 0..n {
        b.vertex(i as u64);
    }
    for i in 0..n as u32 {
        for j in 0..n as u32 {
            if i != j {
                b.edge(i, j);
            }
        }
    }
    let t = b.build();
    for s in STRATEGIES {
        let p = partition_graph(&t, &opts(3, s));
        let cap = ((n as f64) * 1.05 / 3.0).ceil() as usize;
        assert!(
            p.sizes().into_iter().max().unwrap() <= cap,
            "{}: clique overfills a partition ({:?})",
            s.name(),
            p.sizes()
        );
        assert_eq!(p.sizes().iter().sum::<usize>(), n);
    }
}

/// More partitions than vertices: the extras stay empty, nothing panics.
#[test]
fn more_partitions_than_vertices() {
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    for i in 0..3u64 {
        b.vertex(i);
    }
    b.edge(0, 1);
    let t = b.build();
    for s in STRATEGIES {
        let p = partition_graph(&t, &opts(8, s));
        assert_eq!(p.sizes().iter().sum::<usize>(), 3, "{}", s.name());
        assert!(p.assign.iter().all(|&x| x < 8));
    }
}

#[test]
fn strategy_names_round_trip() {
    for s in STRATEGIES {
        assert_eq!(PartitionStrategy::parse(s.name()).unwrap(), s);
    }
    assert!(PartitionStrategy::parse("metis").is_err());
}

// ------------------------------------- subgraph extraction (subgraph.rs)

/// A partition that received no vertices still appears in the output,
/// with zero subgraphs — downstream layout code indexes by part id.
#[test]
fn empty_partition_yields_partition_with_no_subgraphs() {
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    for i in 0..4u64 {
        b.vertex(i);
    }
    b.edge(0, 1);
    b.edge(2, 3);
    let t = b.build();
    // Parts 0 and 2 hold everything; part 1 is empty.
    let p = Partitioning { n_parts: 3, assign: vec![0, 0, 2, 2] };
    let parts = extract_partitions(&t, &p);
    assert_eq!(parts.len(), 3);
    assert_eq!(parts[1].subgraphs.len(), 0);
    assert_eq!(parts[1].n_vertices(), 0);
    assert_eq!(parts[0].subgraphs.len(), 1);
    assert_eq!(parts[2].subgraphs.len(), 1);
    // The empty partition still bin-packs (all bins empty).
    let bp = binpack_subgraphs(&parts[1], 4);
    assert!(bp.bin_major_order().is_empty());
    assert!(bp.weights.iter().all(|&w| w == 0));
}

/// With no edges at all, every vertex is its own maximal component: one
/// singleton subgraph per vertex, no remote edges anywhere.
#[test]
fn all_isolated_vertices_become_singleton_subgraphs() {
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    let n = 12usize;
    for i in 0..n {
        b.vertex(100 + i as u64);
    }
    let t = b.build();
    let p = partition_graph(&t, &opts(3, PartitionStrategy::Fennel));
    let parts = extract_partitions(&t, &p);
    let total_sgs: usize = parts.iter().map(|pt| pt.subgraphs.len()).sum();
    assert_eq!(total_sgs, n, "expected one singleton subgraph per isolated vertex");
    for pt in &parts {
        for sg in &pt.subgraphs {
            assert_eq!(sg.n_vertices(), 1);
            assert_eq!(sg.n_edges(), 0);
            assert!(sg.remote.is_empty());
        }
    }
}

/// One giant component dominates its partition: CC discovery must keep
/// it whole, and LPT bin packing must still cover every subgraph even
/// when a single item dwarfs the bin target.
#[test]
fn giant_component_spans_bins_intact() {
    let mut b = TemplateBuilder::new(Schema::new(vec![]), Schema::new(vec![]));
    let n = 64usize;
    for i in 0..n {
        b.vertex(i as u64);
    }
    // One chain of 60 plus four isolated vertices, all in one partition.
    for i in 0..59u32 {
        b.edge(i, i + 1);
    }
    let t = b.build();
    let p = Partitioning { n_parts: 1, assign: vec![0; n] };
    let parts = extract_partitions(&t, &p);
    let part = &parts[0];
    assert_eq!(part.subgraphs.len(), 5); // the chain + 4 singletons
    let giant = part.subgraphs.iter().map(|s| s.n_vertices()).max().unwrap();
    assert_eq!(giant, 60, "chain split across subgraphs");

    let bp = binpack_subgraphs(part, 4);
    let mut packed: Vec<usize> = bp.bin_major_order();
    packed.sort_unstable();
    assert_eq!(packed, (0..part.subgraphs.len()).collect::<Vec<_>>());
    // The giant lands alone; the singletons share the remaining bins.
    let giant_idx =
        (0..part.subgraphs.len()).max_by_key(|&i| part.subgraphs[i].weight()).unwrap();
    assert_eq!(bp.bins[bp.bin_of(giant_idx)], vec![giant_idx]);
}
