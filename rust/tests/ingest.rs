//! Streaming ingestion (gofs::ingest): crash recovery through the WAL,
//! deploy-vs-ingest equivalence down to the bit level, follow-mode
//! analytics over a live feed, and the byte-budgeted cache envelope.

use goffish::apps::{PageRankApp, SsspApp};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{
    deploy, deploy_template, open_collection, CollectionAppender, DeployConfig, DiskModel,
    IngestOptions, Projection, StoreOptions,
};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use goffish::runtime::ScalarBackend;
use goffish::util::propcheck::forall;
use std::path::PathBuf;
use std::sync::Arc;

const PARTS: usize = 2;
const BINS: usize = 3;
const PACK: usize = 4;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gofs-ingest-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tr_gen() -> TraceRouteGenerator {
    TraceRouteGenerator::new(TraceRouteParams::tiny())
}

fn opts(cache: usize) -> StoreOptions {
    StoreOptions {
        cache_slots: cache,
        disk: DiskModel::instant(),
        metrics: Arc::new(Metrics::new()),
        ..Default::default()
    }
}

fn engine(dir: &PathBuf, cache: usize) -> GopherEngine {
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions {
        cache_slots: cache,
        disk: DiskModel::instant(),
        metrics: metrics.clone(),
        ..Default::default()
    };
    GopherEngine::new(open_collection(dir, &o).unwrap(), ClusterSpec::new(PARTS), metrics)
}

/// Quantized final SSSP distances keyed (subgraph, local vertex).
fn sssp_fingerprint(eng: &GopherEngine, gen: &TraceRouteGenerator, opts: &RunOptions) -> Vec<(u64, u32, i64)> {
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    let stats = eng.run(&app, opts).unwrap();
    assert!(!stats.per_timestep.is_empty());
    let distances = app.results.distances.lock().unwrap();
    let mut out: Vec<(u64, u32, i64)> = distances
        .iter()
        .flat_map(|(sgid, (_, d))| {
            d.iter().enumerate().map(move |(lv, &x)| {
                let q = if x.is_finite() { (x as f64 * 1e6).round() as i64 } else { -1 };
                (sgid.0, lv as u32, q)
            })
        })
        .collect();
    out.sort_unstable();
    out
}

fn pagerank_fingerprint(eng: &GopherEngine, gen: &TraceRouteGenerator, opts: &RunOptions) -> Vec<(u64, i64)> {
    let app = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    eng.run(&app, opts).unwrap();
    let mut out: Vec<(u64, i64)> = (0..3)
        .flat_map(|t| {
            app.results
                .top_k(t, 10)
                .into_iter()
                .map(move |(v, r)| (v, (r as f64 * 1e12).round() as i64))
        })
        .collect();
    out.sort_unstable();
    out
}

/// Every value of every instance must read back identically from the two
/// collections (generic resolution path, so all types are covered).
fn assert_stores_identical(da: &PathBuf, db: &PathBuf, n_ts: usize) {
    let sa = open_collection(da, &opts(16)).unwrap();
    let sb = open_collection(db, &opts(16)).unwrap();
    assert_eq!(sa.len(), sb.len());
    for (a, b) in sa.iter().zip(&sb) {
        assert_eq!(a.n_instances(), n_ts, "store A instance count");
        assert_eq!(b.n_instances(), n_ts, "store B instance count");
        let proj = Projection::all(a.vertex_schema(), a.edge_schema());
        for sg in a.subgraphs() {
            for t in 0..n_ts {
                let ia = a.read_instance(sg.id.local(), t, &proj).unwrap();
                let ib = b.read_instance(sg.id.local(), t, &proj).unwrap();
                assert_eq!(ia.window, ib.window, "window t{t}");
                for attr in 0..a.vertex_schema().len() {
                    for v in 0..sg.n_vertices() as u32 {
                        assert_eq!(
                            ia.vertex_values(attr, v),
                            ib.vertex_values(attr, v),
                            "vattr {attr} v{v} t{t}"
                        );
                    }
                }
                for attr in 0..a.edge_schema().len() {
                    for e in 0..sg.edges.len() {
                        assert_eq!(
                            ia.edge_values(attr, e),
                            ib.edge_values(attr, e),
                            "eattr {attr} e{e} t{t}"
                        );
                    }
                }
            }
        }
    }
}

/// Stream `gen`'s instances `[from, to)` through an appender opened
/// fresh on `dir` (reopening is the crash-recovery path).
fn ingest_range(dir: &PathBuf, gen: &TraceRouteGenerator, from: usize, to: usize) {
    let mut app = CollectionAppender::open(dir, IngestOptions::default()).unwrap();
    assert_eq!(app.n_instances(), from, "appender resumes at the collection's end");
    for t in from..to {
        assert_eq!(app.append(&gen.instance(t)).unwrap(), t);
    }
}

/// Acceptance: an ingested collection is indistinguishable from a
/// batch-deployed one — including a simulated crash mid-group (appender
/// dropped with an unsealed WAL tail, then reopened) — with bit-identical
/// SSSP and PageRank outputs.
#[test]
fn streamed_ingest_is_bit_identical_to_batch_deploy() {
    let gen = tr_gen();
    let n = gen.n_instances(); // 12 = 3 full groups at pack 4
    let cfg = DeployConfig::new(PARTS, BINS, PACK);
    let d_batch = tmpdir("eq-batch");
    deploy(&gen, &cfg, &d_batch).unwrap();
    let d_feed = tmpdir("eq-feed");
    deploy_template(&gen, &cfg, &d_feed).unwrap();

    // First session appends 0..6: one sealed group (0..4) plus two open
    // WAL records, then "crashes" (drop without seal).
    ingest_range(&d_feed, &gen, 0, 6);
    // Recovery session replays the WAL tail and streams the rest.
    ingest_range(&d_feed, &gen, 6, n);

    assert_stores_identical(&d_batch, &d_feed, n);

    let run = RunOptions::default();
    assert_eq!(
        sssp_fingerprint(&engine(&d_batch, 28), &gen, &run),
        sssp_fingerprint(&engine(&d_feed, 28), &gen, &run),
        "SSSP outputs differ between batch deploy and streamed ingest"
    );
    let pr = RunOptions { timesteps: Some(vec![0, 1, 2]), ..Default::default() };
    assert_eq!(
        pagerank_fingerprint(&engine(&d_batch, 28), &gen, &pr),
        pagerank_fingerprint(&engine(&d_feed, 28), &gen, &pr),
        "PageRank outputs differ between batch deploy and streamed ingest"
    );
    std::fs::remove_dir_all(&d_batch).unwrap();
    std::fs::remove_dir_all(&d_feed).unwrap();
}

/// A torn trailing WAL frame (partial write, no fsync completion) is
/// dropped on replay; partitions that did get the record reconcile to
/// the common prefix, and the lost timestep can simply be re-appended.
#[test]
fn torn_wal_record_recovers_to_common_prefix() {
    let gen = tr_gen();
    let cfg = DeployConfig::new(PARTS, BINS, 8); // pack 8: nothing seals
    let d = tmpdir("torn");
    deploy_template(&gen, &cfg, &d).unwrap();
    ingest_range(&d, &gen, 0, 3);

    // Tear the last frame of part-0's WAL mid-payload.
    let wal = d.join("part-0").join("wal.log");
    let bytes = std::fs::read(&wal).unwrap();
    std::fs::write(&wal, &bytes[..bytes.len() - 7]).unwrap();

    let app = CollectionAppender::open(&d, IngestOptions::default()).unwrap();
    assert_eq!(app.n_instances(), 2, "torn record dropped everywhere");
    assert_eq!(app.sealed_instances(), 0);
    drop(app);

    // Re-append the lost timestep (and one more), then compare against a
    // 4-instance batch deployment of the same generator stream.
    ingest_range(&d, &gen, 2, 4);
    let gen4 = TraceRouteGenerator::new(TraceRouteParams {
        n_instances: 4,
        ..TraceRouteParams::tiny()
    });
    let d_batch = tmpdir("torn-batch");
    deploy(&gen4, &cfg, &d_batch).unwrap();
    // Seal the feed's partial tail so both ends are slice-backed.
    let app = CollectionAppender::open(&d, IngestOptions::default()).unwrap();
    let stats = app.finish().unwrap();
    assert_eq!(stats.sealed_groups, 1);
    assert_stores_identical(&d_batch, &d, 4);
    std::fs::remove_dir_all(&d).unwrap();
    std::fs::remove_dir_all(&d_batch).unwrap();
}

/// A corrupted (bit-flipped) trailing record fails its CRC and is
/// dropped, same as a torn one — earlier records survive.
#[test]
fn corrupt_wal_tail_crc_is_dropped() {
    let gen = tr_gen();
    let cfg = DeployConfig::new(PARTS, BINS, 8);
    let d = tmpdir("crc");
    deploy_template(&gen, &cfg, &d).unwrap();
    ingest_range(&d, &gen, 0, 3);
    for p in 0..PARTS {
        let wal = d.join(format!("part-{p}")).join("wal.log");
        let mut bytes = std::fs::read(&wal).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&wal, &bytes).unwrap();
    }
    let app = CollectionAppender::open(&d, IngestOptions::default()).unwrap();
    assert_eq!(app.n_instances(), 2, "corrupt record must not replay");
    std::fs::remove_dir_all(&d).unwrap();
}

/// Crash window between "publish sealed group" and "truncate WAL":
/// sealed records still in the WAL are skipped on replay (idempotent),
/// never re-applied or double-counted.
#[test]
fn replay_after_publish_before_truncate_is_idempotent() {
    let gen = tr_gen();
    let cfg = DeployConfig::new(PARTS, BINS, PACK);
    let d = tmpdir("idem");
    deploy_template(&gen, &cfg, &d).unwrap();
    ingest_range(&d, &gen, 0, 3);
    // Stash the WALs holding t0..t2, let t3 trigger the seal (which
    // truncates them), then restore the stale WALs — exactly the state a
    // crash between publish and truncate leaves behind.
    let stashed: Vec<(PathBuf, Vec<u8>)> = (0..PARTS)
        .map(|p| {
            let path = d.join(format!("part-{p}")).join("wal.log");
            let bytes = std::fs::read(&path).unwrap();
            (path, bytes)
        })
        .collect();
    ingest_range(&d, &gen, 3, 4);
    for (path, bytes) in &stashed {
        std::fs::write(path, bytes).unwrap();
    }
    let app = CollectionAppender::open(&d, IngestOptions::default()).unwrap();
    assert_eq!(app.sealed_instances(), PACK);
    assert_eq!(app.n_instances(), PACK, "stale WAL records must be skipped");
    drop(app);
    let d_batch = tmpdir("idem-batch");
    let gen4 = TraceRouteGenerator::new(TraceRouteParams {
        n_instances: 4,
        ..TraceRouteParams::tiny()
    });
    deploy(&gen4, &cfg, &d_batch).unwrap();
    assert_stores_identical(&d_batch, &d, 4);
    std::fs::remove_dir_all(&d).unwrap();
    std::fs::remove_dir_all(&d_batch).unwrap();
}

/// Acceptance: `RunOptions::follow` processes timesteps appended after
/// the run started, produces outputs bit-identical to a batch run over
/// the final collection, and never re-reads already-sealed groups (its
/// total slice reads cannot exceed the batch run's — tail-served
/// timesteps cost zero reads, asserted via the ReadTrace-backed
/// per-timestep counters).
#[test]
fn follow_mode_tracks_live_ingest_without_rereading_sealed_groups() {
    let gen = tr_gen();
    let n = gen.n_instances();
    let cfg = DeployConfig::new(PARTS, BINS, PACK);
    let d_feed = tmpdir("follow-feed");
    deploy_template(&gen, &cfg, &d_feed).unwrap();
    let d_batch = tmpdir("follow-batch");
    deploy(&gen, &cfg, &d_batch).unwrap();

    let feed_dir = d_feed.clone();
    let feeder = std::thread::spawn(move || {
        let gen = tr_gen();
        // Give the follow run a head start so every timestep arrives
        // after it is already polling.
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut app = CollectionAppender::open(&feed_dir, IngestOptions::default()).unwrap();
        for t in 0..gen.n_instances() {
            app.append(&gen.instance(t)).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(15));
        }
    });

    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let eng = engine(&d_feed, 64);
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    let follow_opts = RunOptions {
        follow: true,
        follow_poll_ms: 10,
        follow_idle_polls: 300, // 3s of slack over the feed cadence
        prefetch_depth: 3,
        ..Default::default()
    };
    let stats = eng.run(&app, &follow_opts).unwrap();
    feeder.join().unwrap();
    assert_eq!(stats.per_timestep.len(), n, "follow run missed timesteps");
    let follow_reads: u64 = stats.per_timestep.iter().map(|t| t.slices_read).sum();
    let follow_fp = {
        let distances = app.results.distances.lock().unwrap();
        let mut out: Vec<(u64, u32, i64)> = distances
            .iter()
            .flat_map(|(sgid, (_, d))| {
                d.iter().enumerate().map(move |(lv, &x)| {
                    let q = if x.is_finite() { (x as f64 * 1e6).round() as i64 } else { -1 };
                    (sgid.0, lv as u32, q)
                })
            })
            .collect();
        out.sort_unstable();
        out
    };

    let eng_batch = engine(&d_batch, 64);
    let batch_app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    let batch_stats = eng_batch.run(&batch_app, &RunOptions::default()).unwrap();
    let batch_reads: u64 = batch_stats.per_timestep.iter().map(|t| t.slices_read).sum();
    let batch_fp = {
        let distances = batch_app.results.distances.lock().unwrap();
        let mut out: Vec<(u64, u32, i64)> = distances
            .iter()
            .flat_map(|(sgid, (_, d))| {
                d.iter().enumerate().map(move |(lv, &x)| {
                    let q = if x.is_finite() { (x as f64 * 1e6).round() as i64 } else { -1 };
                    (sgid.0, lv as u32, q)
                })
            })
            .collect();
        out.sort_unstable();
        out
    };

    assert_eq!(follow_fp, batch_fp, "follow-mode SSSP diverged from the batch run");
    assert!(batch_reads > 0);
    assert!(
        follow_reads <= batch_reads,
        "follow mode re-read sealed groups: {follow_reads} reads vs batch {batch_reads}"
    );
    std::fs::remove_dir_all(&d_feed).unwrap();
    std::fs::remove_dir_all(&d_batch).unwrap();
}

/// Satellite: the byte-budgeted cache keeps the decoded-slice footprint
/// inside the envelope while a whole-series scan (the ingest+analytics
/// co-residence scenario) streams through it — and reads stay correct.
#[test]
fn byte_budget_bounds_resident_bytes_during_scan() {
    let gen = tr_gen();
    let d = tmpdir("budget");
    deploy(&gen, &DeployConfig::new(1, BINS, PACK), &d).unwrap();

    // Measure the full decoded footprint first (slots sized so nothing
    // evicts), then re-run under a budget of a third of it: big enough
    // for any single slice, small enough that eviction must engage.
    let reference_stores = open_collection(&d, &opts(4096)).unwrap();
    let reference = &reference_stores[0];
    let proj = Projection::all(reference.vertex_schema(), reference.edge_schema());
    let scan = |store: &goffish::gofs::Store| {
        for t in 0..store.n_instances() {
            for sg in store.subgraphs() {
                let _ = store.read_instance(sg.id.local(), t, &proj).unwrap();
            }
        }
    };
    scan(reference);
    let full = reference.cache_resident_bytes();
    assert!(full > 0);
    let budget = (full / 3).max(1);

    let bounded = StoreOptions {
        cache_slots: 4096,
        cache_bytes: budget,
        disk: DiskModel::instant(),
        metrics: Arc::new(Metrics::new()),
        ..Default::default()
    };
    let bounded_stores = open_collection(&d, &bounded).unwrap();
    let store = &bounded_stores[0];
    let mut checked = 0usize;
    for t in 0..store.n_instances() {
        for sg in store.subgraphs() {
            let got = store.read_instance(sg.id.local(), t, &proj).unwrap();
            let want = reference.read_instance(sg.id.local(), t, &proj).unwrap();
            for e in 0..sg.edges.len() {
                assert_eq!(
                    got.edge_values(traceroute::eattr::LATENCY_MS, e),
                    want.edge_values(traceroute::eattr::LATENCY_MS, e)
                );
                checked += 1;
            }
        }
        assert!(
            store.cache_resident_bytes() <= budget,
            "resident {} exceeds budget {budget} at t{t}",
            store.cache_resident_bytes()
        );
    }
    assert!(checked > 100);
    let (_, _, evictions) = store.cache_stats();
    assert!(evictions > 0, "a third of the full footprint should force eviction");
    std::fs::remove_dir_all(&d).unwrap();
}

/// Property: for random layouts, crash points and partial final groups,
/// ingest-then-seal reads back exactly what batch deploy writes.
#[test]
fn ingest_matches_deploy_property() {
    forall(6, |g| {
        let parts = g.usize(1..3);
        let bins = g.usize(1..4);
        let pack = g.usize(1..5);
        let n = g.usize(1..9);
        let crash_at = g.usize(0..n + 1);
        let gen = TraceRouteGenerator::new(TraceRouteParams {
            n_instances: n,
            ..TraceRouteParams::tiny()
        });
        let cfg = DeployConfig::new(parts, bins, pack);
        let d_batch = tmpdir(&format!("prop-batch-{parts}-{bins}-{pack}-{n}-{crash_at}"));
        deploy(&gen, &cfg, &d_batch).unwrap();
        let d_feed = tmpdir(&format!("prop-feed-{parts}-{bins}-{pack}-{n}-{crash_at}"));
        deploy_template(&gen, &cfg, &d_feed).unwrap();
        ingest_range(&d_feed, &gen, 0, crash_at);
        ingest_range(&d_feed, &gen, crash_at, n);
        // Batch deploy seals a partial final group; match it.
        let app = CollectionAppender::open(&d_feed, IngestOptions::default()).unwrap();
        app.finish().unwrap();
        assert_stores_identical(&d_batch, &d_feed, n);
        std::fs::remove_dir_all(&d_batch).unwrap();
        std::fs::remove_dir_all(&d_feed).unwrap();
    });
}

/// Satellite (WAL group commit): `IngestOptions::group_commit(k)` fsyncs
/// once per k appends, seals/finish flush durably, and the relaxed
/// cadence changes nothing about what reads back.
#[test]
fn group_commit_syncs_once_per_k_appends_and_reads_back_identically() {
    let gen = tr_gen();
    let n = 5usize;
    let cfg = DeployConfig::new(PARTS, BINS, 8); // pack 8: no mid-run seal
    let d_gc = tmpdir("gc-feed");
    deploy_template(&gen, &cfg, &d_gc).unwrap();

    let mut app =
        CollectionAppender::open(&d_gc, IngestOptions::default().group_commit(2)).unwrap();
    for t in 0..n {
        app.append(&gen.instance(t)).unwrap();
    }
    // Appends 2 and 4 hit the commit boundary: 2 synced appends x PARTS.
    let mid = app.stats();
    assert_eq!(mid.appended, n as u64);
    assert_eq!(mid.wal_syncs, 2 * PARTS as u64, "one fsync per k appends per partition");
    // Explicit flush covers the odd trailing append; a second is a no-op.
    app.flush().unwrap();
    assert_eq!(app.stats().wal_syncs, 3 * PARTS as u64);
    app.flush().unwrap();
    assert_eq!(app.stats().wal_syncs, 3 * PARTS as u64);
    let stats = app.finish().unwrap();
    assert_eq!(stats.sealed_groups, 1, "finish seals the short tail durably");

    // Bit-identical to a batch deployment of the same prefix.
    let gen5 = TraceRouteGenerator::new(TraceRouteParams {
        n_instances: n,
        ..TraceRouteParams::tiny()
    });
    let d_batch = tmpdir("gc-batch");
    deploy(&gen5, &cfg, &d_batch).unwrap();
    assert_stores_identical(&d_batch, &d_gc, n);

    // Per-append fsync stays the default cadence.
    let d_def = tmpdir("gc-default");
    deploy_template(&gen, &cfg, &d_def).unwrap();
    let mut app = CollectionAppender::open(&d_def, IngestOptions::default()).unwrap();
    for t in 0..3 {
        app.append(&gen.instance(t)).unwrap();
    }
    assert_eq!(app.stats().wal_syncs, 3 * PARTS as u64);
    // Unflushed group-commit appends still replay in-process (the
    // bytes are written, just not fsynced): only an OS crash can lose
    // the unsynced suffix.
    drop(app);
    let d_unsynced = tmpdir("gc-unsynced");
    deploy_template(&gen, &cfg, &d_unsynced).unwrap();
    let mut app =
        CollectionAppender::open(&d_unsynced, IngestOptions::default().group_commit(4)).unwrap();
    for t in 0..3 {
        app.append(&gen.instance(t)).unwrap();
    }
    assert_eq!(app.stats().wal_syncs, 0);
    drop(app); // "process crash" without flush
    let app = CollectionAppender::open(&d_unsynced, IngestOptions::default()).unwrap();
    assert_eq!(app.n_instances(), 3);
    drop(app);
    // A no-sync appender never accumulates pending fsyncs: flush stays
    // a no-op regardless of group_commit.
    let mut app = CollectionAppender::open(
        &d_unsynced,
        IngestOptions { sync: false, ..Default::default() }.group_commit(2),
    )
    .unwrap();
    app.append(&gen.instance(3)).unwrap();
    app.append(&gen.instance(4)).unwrap();
    app.flush().unwrap();
    assert_eq!(app.stats().wal_syncs, 0, "flush must no-op when sync is off");
    std::fs::remove_dir_all(&d_gc).unwrap();
    std::fs::remove_dir_all(&d_batch).unwrap();
    std::fs::remove_dir_all(&d_def).unwrap();
    std::fs::remove_dir_all(&d_unsynced).unwrap();
}

/// Satellite (follow-mode backpressure): with a tail high-water mark
/// set, an appender attached to the engine's flow gate blocks while the
/// follow run lags, the probe counter records it, every timestep still
/// lands exactly once, and outputs match a batch run.
#[test]
fn backpressure_gate_blocks_fast_feeder_behind_slow_follow_run() {
    use goffish::gofs::SubgraphInstance;
    use goffish::gopher::{Application, ComputeCtx, Pattern, Payload, SubgraphProgram};
    use goffish::graph::Schema;
    use goffish::partition::Subgraph;

    let gen = tr_gen();
    let n = gen.n_instances();
    let cfg = DeployConfig::new(PARTS, BINS, PACK);
    let d_feed = tmpdir("bp-feed");
    deploy_template(&gen, &cfg, &d_feed).unwrap();

    // Stores carry a 1-byte high-water mark: any uncomputed tail byte
    // throttles the feeder to lockstep with the run.
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions {
        cache_slots: 64,
        tail_high_water_bytes: 1,
        disk: DiskModel::instant(),
        metrics: metrics.clone(),
        ..Default::default()
    };
    let eng = GopherEngine::new(
        open_collection(&d_feed, &o).unwrap(),
        ClusterSpec::new(PARTS),
        metrics,
    );
    assert_eq!(eng.flow_gate().hwm_bytes(), 1);

    let gate = eng.flow_gate();
    let feed_dir = d_feed.clone();
    let feeder = std::thread::spawn(move || {
        let gen = tr_gen();
        // Head start: the run is already polling (and publishing lag)
        // before the first append lands.
        std::thread::sleep(std::time::Duration::from_millis(60));
        let mut app = CollectionAppender::open(&feed_dir, IngestOptions::default()).unwrap();
        app.attach_gate(gate);
        for t in 0..gen.n_instances() {
            app.append(&gen.instance(t)).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        app.stats()
    });

    /// A deliberately slow consumer: ~20ms per timestep.
    struct SlowCount;
    struct SlowProgram;
    impl SubgraphProgram for SlowProgram {
        fn compute(&mut self, ctx: &mut ComputeCtx<'_>, _sgi: &SubgraphInstance, _m: &[Payload]) {
            if ctx.superstep == 1 && ctx.sgid.local() == 0 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            ctx.vote_to_halt();
        }
    }
    impl Application for SlowCount {
        fn name(&self) -> &str {
            "slow-count"
        }
        fn pattern(&self) -> Pattern {
            Pattern::Sequential
        }
        fn projection(&self, vs: &Schema, es: &Schema) -> Projection {
            Projection::all(vs, es)
        }
        fn create(&self, _sg: &Subgraph) -> Box<dyn SubgraphProgram> {
            Box::new(SlowProgram)
        }
    }

    let stats = eng
        .run(
            &SlowCount,
            &RunOptions {
                follow: true,
                follow_poll_ms: 2,
                follow_idle_polls: 750, // ~1.5s of slack over the blocked cadence
                ..Default::default()
            },
        )
        .unwrap();
    let feeder_stats = feeder.join().unwrap();
    assert_eq!(stats.per_timestep.len(), n, "backpressure lost timesteps");
    assert!(
        feeder_stats.backpressure_blocks > 0,
        "a 1-byte mark against a 20ms/timestep consumer must block the feeder"
    );
    assert!(feeder_stats.backpressure_wall_s > 0.0);
    assert_eq!(feeder_stats.appended, n as u64);

    // The throttled feed still yields the batch-identical collection.
    let d_batch = tmpdir("bp-batch");
    deploy(&gen, &cfg, &d_batch).unwrap();
    let app = CollectionAppender::open(&d_feed, IngestOptions::default()).unwrap();
    app.finish().unwrap();
    assert_stores_identical(&d_batch, &d_feed, n);
    std::fs::remove_dir_all(&d_feed).unwrap();
    std::fs::remove_dir_all(&d_batch).unwrap();
}
