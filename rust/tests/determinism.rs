//! Determinism and concurrency-independence properties of the engine:
//! results must not depend on worker counts, temporal parallelism, or
//! cache configuration — only on the data and the algorithm.

use goffish::apps::{NHopApp, PageRankApp, SsspApp, WccApp};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{
    deploy, open_collection, repartition_collection, DeployConfig, DiskModel,
    RepartitionOptions, StoreOptions,
};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use goffish::partition::PartitionStrategy;
use goffish::runtime::ScalarBackend;
use std::path::PathBuf;
use std::sync::Arc;

fn deployed(tag: &str) -> (TraceRouteGenerator, PathBuf) {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = std::env::temp_dir().join(format!("goffish-det-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    deploy(&gen, &DeployConfig::new(3, 4, 3), &dir).unwrap();
    (gen, dir)
}

fn engine(dir: &PathBuf) -> GopherEngine {
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions { cache_slots: 16, disk: DiskModel::instant(), metrics: metrics.clone(), ..Default::default() };
    GopherEngine::new(open_collection(dir, &o).unwrap(), ClusterSpec::new(3), metrics)
}

fn pagerank_fingerprint(eng: &GopherEngine, gen: &TraceRouteGenerator, opts: &RunOptions) -> Vec<(u64, i64)> {
    let app = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    eng.run(&app, opts).unwrap();
    let mut out: Vec<(u64, i64)> = (0..3)
        .flat_map(|t| {
            app.results
                .top_k(t, 10)
                .into_iter()
                .map(move |(v, r)| (v, (r as f64 * 1e12).round() as i64))
        })
        .collect();
    out.sort_unstable();
    out
}

#[test]
fn pagerank_invariant_to_worker_counts() {
    let (gen, dir) = deployed("workers");
    let eng = engine(&dir);
    let base = RunOptions { timesteps: Some(vec![0, 1, 2]), ..Default::default() };
    let r1 = pagerank_fingerprint(&eng, &gen, &RunOptions { workers: 1, temporal_workers: 1, ..base.clone() });
    let r8 = pagerank_fingerprint(&eng, &gen, &RunOptions { workers: 8, temporal_workers: 3, ..base.clone() });
    assert_eq!(r1, r8, "parallelism changed PageRank results");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn repeated_runs_identical() {
    let (gen, dir) = deployed("repeat");
    let eng = engine(&dir);
    let opts = RunOptions { timesteps: Some(vec![0, 1, 2]), ..Default::default() };
    let a = pagerank_fingerprint(&eng, &gen, &opts);
    let b = pagerank_fingerprint(&eng, &gen, &opts);
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn nhop_composite_invariant_to_temporal_parallelism() {
    let (gen, dir) = deployed("nhop");
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let totals: Vec<u64> = [1usize, 4]
        .iter()
        .map(|&tw| {
            let eng = engine(&dir);
            let mut app = NHopApp::new(source, 4, traceroute::eattr::LATENCY_MS);
            app.hist_hi = 2000.0;
            eng.run(
                &app,
                &RunOptions {
                    timesteps: Some((0..6).collect()),
                    temporal_workers: tw,
                    ..Default::default()
                },
            )
            .unwrap();
            let composite = app.results.composite.lock().unwrap();
            composite.as_ref().unwrap().total()
        })
        .collect();
    assert_eq!(totals[0], totals[1], "temporal parallelism changed merge result");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Quantized final SSSP distances keyed (subgraph, local vertex) — the
/// sequential pattern exercises the cross-timestep carry that the
/// prefetcher pipelines around.
fn sssp_fingerprint(eng: &GopherEngine, gen: &TraceRouteGenerator, opts: &RunOptions) -> Vec<(u64, u32, i64)> {
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    eng.run(&app, opts).unwrap();
    let distances = app.results.distances.lock().unwrap();
    let mut out: Vec<(u64, u32, i64)> = distances
        .iter()
        .flat_map(|(sgid, (_, d))| {
            d.iter().enumerate().map(move |(lv, &x)| {
                let q = if x.is_finite() { (x as f64 * 1e6).round() as i64 } else { -1 };
                (sgid.0, lv as u32, q)
            })
        })
        .collect();
    out.sort_unstable();
    out
}

/// Loading timestep t+1 while t computes must not change any result —
/// prefetching only moves work earlier in wall-clock, never reorders
/// message delivery or carried state.
#[test]
fn prefetching_does_not_change_sequential_results() {
    let (gen, dir) = deployed("prefetch");
    let base = RunOptions { timesteps: Some((0..6).collect()), ..Default::default() };
    let with = sssp_fingerprint(
        &engine(&dir),
        &gen,
        &RunOptions { prefetch: true, ..base.clone() },
    );
    let without = sssp_fingerprint(
        &engine(&dir),
        &gen,
        &RunOptions { prefetch: false, workers: 1, ..base.clone() },
    );
    assert!(!with.is_empty());
    assert_eq!(with, without, "prefetch/parallel load changed SSSP results");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Prefetching must also leave the independent-pattern merge/fingerprint
/// machinery untouched (it only engages for the sequential pattern).
#[test]
fn prefetch_flag_is_inert_for_independent_pattern() {
    let (gen, dir) = deployed("prefetch-ind");
    let base = RunOptions { timesteps: Some(vec![0, 1, 2]), ..Default::default() };
    let a = pagerank_fingerprint(&engine(&dir), &gen, &RunOptions { prefetch: true, ..base.clone() });
    let b = pagerank_fingerprint(&engine(&dir), &gen, &RunOptions { prefetch: false, ..base.clone() });
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn stats_accounting_consistent() {
    let (gen, dir) = deployed("stats");
    let eng = engine(&dir);
    let app = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    let stats = eng
        .run(&app, &RunOptions { timesteps: Some(vec![0, 1]), temporal_workers: 1, ..Default::default() })
        .unwrap();
    assert_eq!(stats.per_timestep.len(), 2);
    for ts in &stats.per_timestep {
        // Fixed-iteration PR: supersteps = iterations + 1.
        assert_eq!(ts.supersteps, app.iterations + 1);
        assert!(ts.wall_s > 0.0);
        // cache misses <= slices read (each miss is exactly one read)
        assert_eq!(ts.cache_misses, ts.slices_read);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Per-timestep observables that must not depend on routing mode.
fn stats_fingerprint(stats: &goffish::gopher::RunStats) -> Vec<(usize, usize, u64, u64, u64)> {
    stats
        .per_timestep
        .iter()
        .map(|t| (t.timestep, t.supersteps, t.msgs_local, t.msgs_remote, t.msg_bytes_remote))
        .collect()
}

/// Tentpole (overlapped superstep routing): staging outboxes from the
/// compute workers must leave every observable bit-identical to the
/// single-threaded barrier drain — app outputs AND per-timestep stats —
/// across all three patterns (SSSP sequential, PageRank independent,
/// WCC independent/structural).
#[test]
fn overlapped_routing_is_bit_identical_to_sequential_drain() {
    let (gen, dir) = deployed("route");
    let seq = |overlap: bool| RunOptions {
        timesteps: Some((0..6).collect()),
        overlap_routing: overlap,
        ..Default::default()
    };

    // SSSP: cross-timestep carry + multi-superstep frontier expansion.
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let run_sssp = |overlap: bool| {
        let eng = engine(&dir);
        let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
        let stats = eng.run(&app, &seq(overlap)).unwrap();
        let distances = app.results.distances.lock().unwrap();
        let mut out: Vec<(u64, u32, i64)> = distances
            .iter()
            .flat_map(|(sgid, (_, d))| {
                d.iter().enumerate().map(move |(lv, &x)| {
                    let q = if x.is_finite() { (x as f64 * 1e6).round() as i64 } else { -1 };
                    (sgid.0, lv as u32, q)
                })
            })
            .collect();
        out.sort_unstable();
        (out, stats_fingerprint(&stats))
    };
    let (fp_on, st_on) = run_sssp(true);
    let (fp_off, st_off) = run_sssp(false);
    assert!(!fp_on.is_empty());
    assert_eq!(fp_on, fp_off, "overlapped routing changed SSSP outputs");
    assert_eq!(st_on, st_off, "overlapped routing changed SSSP per-timestep stats");

    // PageRank over the temporal pool (both pool prefetch modes).
    for prefetch in [true, false] {
        let base = RunOptions {
            timesteps: Some(vec![0, 1, 2]),
            prefetch,
            temporal_workers: 3,
            ..Default::default()
        };
        let on = pagerank_fingerprint(
            &engine(&dir),
            &gen,
            &RunOptions { overlap_routing: true, ..base.clone() },
        );
        let off = pagerank_fingerprint(
            &engine(&dir),
            &gen,
            &RunOptions { overlap_routing: false, ..base.clone() },
        );
        assert_eq!(on, off, "overlapped routing changed PageRank (prefetch={prefetch})");
    }

    // WCC: boundary-label exchange on timestep 0.
    let run_wcc = |overlap: bool| {
        let eng = engine(&dir);
        let app = goffish::apps::WccApp::new();
        let stats = eng
            .run(
                &app,
                &RunOptions {
                    timesteps: Some(vec![0]),
                    overlap_routing: overlap,
                    ..Default::default()
                },
            )
            .unwrap();
        let mut labels: Vec<(u64, u64)> =
            app.results.labels.lock().unwrap().iter().map(|(k, &v)| (k.0, v)).collect();
        labels.sort_unstable();
        (labels, stats_fingerprint(&stats))
    };
    let (wcc_on, wst_on) = run_wcc(true);
    let (wcc_off, wst_off) = run_wcc(false);
    assert!(!wcc_on.is_empty());
    assert_eq!(wcc_on, wcc_off, "overlapped routing changed WCC labels");
    assert_eq!(wst_on, wst_off, "overlapped routing changed WCC stats");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite (parallel barrier delivery): the per-destination delivery
/// loop fans out over the worker pool when more than one destination has
/// traffic; with a single worker it stays the serial drain. Outputs AND
/// per-timestep stats must be bit-identical across worker counts in both
/// routing modes — destinations are disjoint, so the fan-out cannot
/// change anything a destination observes.
#[test]
fn parallel_delivery_is_bit_identical_to_serial_drain() {
    let (gen, dir) = deployed("deliver");
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    for overlap in [true, false] {
        let run = |workers: usize| {
            let eng = engine(&dir);
            let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
            let stats = eng
                .run(
                    &app,
                    &RunOptions {
                        timesteps: Some((0..6).collect()),
                        overlap_routing: overlap,
                        workers,
                        ..Default::default()
                    },
                )
                .unwrap();
            let distances = app.results.distances.lock().unwrap();
            let mut out: Vec<(u64, u32, i64)> = distances
                .iter()
                .flat_map(|(sgid, (_, d))| {
                    d.iter().enumerate().map(move |(lv, &x)| {
                        let q =
                            if x.is_finite() { (x as f64 * 1e6).round() as i64 } else { -1 };
                        (sgid.0, lv as u32, q)
                    })
                })
                .collect();
            out.sort_unstable();
            (out, stats_fingerprint(&stats))
        };
        let (fp1, st1) = run(1);
        let (fp8, st8) = run(8);
        assert!(!fp1.is_empty());
        assert_eq!(fp1, fp8, "parallel delivery changed SSSP outputs (overlap={overlap})");
        assert_eq!(st1, st8, "parallel delivery changed stats (overlap={overlap})");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Tentpole (temporal-pool prefetch): the shared prefetch queue must not
/// change independent/eventually-dependent results — only the wall-clock
/// split. (The merge path is covered by NHop's composite.)
#[test]
fn temporal_pool_prefetch_does_not_change_results() {
    let (gen, dir) = deployed("pool-prefetch");
    let base = RunOptions {
        timesteps: Some((0..6).collect()),
        temporal_workers: 3,
        ..Default::default()
    };
    let with = pagerank_fingerprint(
        &engine(&dir),
        &gen,
        &RunOptions { prefetch: true, ..base.clone() },
    );
    let without = pagerank_fingerprint(
        &engine(&dir),
        &gen,
        &RunOptions { prefetch: false, ..base.clone() },
    );
    assert_eq!(with, without, "pool prefetch changed PageRank results");

    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let totals: Vec<u64> = [true, false]
        .iter()
        .map(|&prefetch| {
            let eng = engine(&dir);
            let mut app = NHopApp::new(source, 4, traceroute::eattr::LATENCY_MS);
            app.hist_hi = 2000.0;
            eng.run(&app, &RunOptions { prefetch, ..base.clone() }).unwrap();
            let composite = app.results.composite.lock().unwrap();
            composite.as_ref().unwrap().total()
        })
        .collect();
    assert_eq!(totals[0], totals[1], "pool prefetch changed the merge result");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ===================== partitioner invariance (PR 10) =====================
//
// Analytics must be a pure function of the data, not of the vertex→host
// placement. These tests deploy the same generated collection under all
// three `--partitioner` strategies and require bit-identical canonical
// outputs — keyed by *external* vertex id, since subgraph ids are
// placement-dependent — for the three gate apps, and across an offline
// drift re-partition of a live deployment.

fn deployed_as(tag: &str, strategy: PartitionStrategy) -> (TraceRouteGenerator, PathBuf) {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = std::env::temp_dir().join(format!("goffish-det-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = DeployConfig::new(3, 4, 3);
    cfg.partition.strategy = strategy;
    deploy(&gen, &cfg, &dir).unwrap();
    (gen, dir)
}

/// Final SSSP distances keyed (ext id → f32 bits). The label-correcting
/// fixpoint is a min over per-path f32 sums, each accumulated along its
/// path in path order — nothing in it depends on the partitioning.
fn sssp_canonical(dir: &PathBuf, gen: &TraceRouteGenerator) -> Vec<(u64, u32)> {
    let eng = engine(dir);
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    eng.run(&app, &RunOptions { timesteps: Some((0..6).collect()), ..Default::default() })
        .unwrap();
    let distances = app.results.distances.lock().unwrap();
    let mut out: Vec<(u64, u32)> = Vec::new();
    for s in eng.stores() {
        for sg in s.subgraphs() {
            if let Some((_, d)) = distances.get(&sg.id) {
                for (lv, &x) in d.iter().enumerate() {
                    out.push((sg.ext_ids[lv], x.to_bits()));
                }
            }
        }
    }
    out.sort_unstable();
    out
}

/// Full per-vertex PageRank bits keyed (timestep, ext id) — recorded via
/// `record_ranks`, exact across placements because contributions are
/// dyadic-grid quantized before the order-varying reduction.
fn pagerank_canonical(dir: &PathBuf, gen: &TraceRouteGenerator) -> Vec<((usize, u64), u32)> {
    let eng = engine(dir);
    let mut app = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    app.record_ranks = true;
    eng.run(&app, &RunOptions { timesteps: Some(vec![0, 1, 2]), ..Default::default() })
        .unwrap();
    let ranks = app.results.ranks_by_vertex.lock().unwrap();
    let mut out: Vec<((usize, u64), u32)> = ranks.iter().map(|(&k, &v)| (k, v)).collect();
    out.sort_unstable();
    out
}

/// WCC labels keyed (ext id → component min-ext-id).
fn wcc_canonical(dir: &PathBuf) -> Vec<(u64, u64)> {
    let eng = engine(dir);
    let app = WccApp::new();
    eng.run(&app, &RunOptions { timesteps: Some(vec![0]), ..Default::default() }).unwrap();
    let labels = app.results.labels.lock().unwrap();
    let mut out: Vec<(u64, u64)> = Vec::new();
    for s in eng.stores() {
        for sg in s.subgraphs() {
            let label = labels[&sg.id];
            for &ext in &sg.ext_ids {
                out.push((ext, label));
            }
        }
    }
    out.sort_unstable();
    out
}

#[test]
fn outputs_bit_identical_across_partitioners() {
    let (gen, ldg) = deployed_as("part-ldg", PartitionStrategy::Ldg);
    let sssp_ref = sssp_canonical(&ldg, &gen);
    let pr_ref = pagerank_canonical(&ldg, &gen);
    let wcc_ref = wcc_canonical(&ldg);
    assert!(!sssp_ref.is_empty() && !pr_ref.is_empty() && !wcc_ref.is_empty());
    std::fs::remove_dir_all(&ldg).unwrap();

    for strategy in [PartitionStrategy::Fennel, PartitionStrategy::Binpack] {
        let tag = format!("part-{}", strategy.name());
        let (gen2, dir) = deployed_as(&tag, strategy);
        assert_eq!(
            sssp_canonical(&dir, &gen2),
            sssp_ref,
            "{}: SSSP distances differ from the ldg deployment",
            strategy.name()
        );
        assert_eq!(
            pagerank_canonical(&dir, &gen2),
            pr_ref,
            "{}: PageRank bits differ from the ldg deployment",
            strategy.name()
        );
        assert_eq!(
            wcc_canonical(&dir),
            wcc_ref,
            "{}: WCC labels differ from the ldg deployment",
            strategy.name()
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

/// The offline drift re-partition rewrites every partition of a sealed
/// collection — vertex placement, subgraph extraction, bins, attribute
/// slices — and none of the canonical outputs may move a bit. The
/// traffic signal comes from a real run's routed-pair totals, closing
/// the loop the CLI exposes (`run --traffic-out` → `compact
/// --repartition --traffic`).
#[test]
fn repartition_preserves_all_outputs_bit_identical() {
    let (gen, dir) = deployed_as("repart", PartitionStrategy::Ldg);
    let sssp_before = sssp_canonical(&dir, &gen);
    let pr_before = pagerank_canonical(&dir, &gen);
    let wcc_before = wcc_canonical(&dir);

    // Harvest a drift signal from a real run.
    let traffic = {
        let eng = engine(&dir);
        let source = gen.template().ext_ids[gen.vantages()[0] as usize];
        let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
        let stats = eng
            .run(&app, &RunOptions { timesteps: Some((0..6).collect()), ..Default::default() })
            .unwrap();
        stats.routed_pair_totals()
    };

    let rep = repartition_collection(
        &dir,
        &RepartitionOptions {
            strategy: Some(PartitionStrategy::Fennel),
            traffic,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(
        rep.moved_vertices > 0,
        "fennel re-placement unexpectedly identical to the ldg layout"
    );
    assert_eq!(rep.parts, 3);

    assert_eq!(sssp_canonical(&dir, &gen), sssp_before, "re-partition changed SSSP");
    assert_eq!(pagerank_canonical(&dir, &gen), pr_before, "re-partition changed PageRank");
    assert_eq!(wcc_canonical(&dir), wcc_before, "re-partition changed WCC");
    std::fs::remove_dir_all(&dir).unwrap();
}
