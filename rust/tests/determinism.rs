//! Determinism and concurrency-independence properties of the engine:
//! results must not depend on worker counts, temporal parallelism, or
//! cache configuration — only on the data and the algorithm.

use goffish::apps::{NHopApp, PageRankApp, SsspApp};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{deploy, open_collection, DeployConfig, DiskModel, StoreOptions};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use goffish::runtime::ScalarBackend;
use std::path::PathBuf;
use std::sync::Arc;

fn deployed(tag: &str) -> (TraceRouteGenerator, PathBuf) {
    let gen = TraceRouteGenerator::new(TraceRouteParams::tiny());
    let dir = std::env::temp_dir().join(format!("goffish-det-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    deploy(&gen, &DeployConfig::new(3, 4, 3), &dir).unwrap();
    (gen, dir)
}

fn engine(dir: &PathBuf) -> GopherEngine {
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions { cache_slots: 16, disk: DiskModel::instant(), metrics: metrics.clone(), ..Default::default() };
    GopherEngine::new(open_collection(dir, &o).unwrap(), ClusterSpec::new(3), metrics)
}

fn pagerank_fingerprint(eng: &GopherEngine, gen: &TraceRouteGenerator, opts: &RunOptions) -> Vec<(u64, i64)> {
    let app = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    eng.run(&app, opts).unwrap();
    let mut out: Vec<(u64, i64)> = (0..3)
        .flat_map(|t| {
            app.results
                .top_k(t, 10)
                .into_iter()
                .map(move |(v, r)| (v, (r as f64 * 1e12).round() as i64))
        })
        .collect();
    out.sort_unstable();
    out
}

#[test]
fn pagerank_invariant_to_worker_counts() {
    let (gen, dir) = deployed("workers");
    let eng = engine(&dir);
    let base = RunOptions { timesteps: Some(vec![0, 1, 2]), ..Default::default() };
    let r1 = pagerank_fingerprint(&eng, &gen, &RunOptions { workers: 1, temporal_workers: 1, ..base.clone() });
    let r8 = pagerank_fingerprint(&eng, &gen, &RunOptions { workers: 8, temporal_workers: 3, ..base.clone() });
    assert_eq!(r1, r8, "parallelism changed PageRank results");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn repeated_runs_identical() {
    let (gen, dir) = deployed("repeat");
    let eng = engine(&dir);
    let opts = RunOptions { timesteps: Some(vec![0, 1, 2]), ..Default::default() };
    let a = pagerank_fingerprint(&eng, &gen, &opts);
    let b = pagerank_fingerprint(&eng, &gen, &opts);
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn nhop_composite_invariant_to_temporal_parallelism() {
    let (gen, dir) = deployed("nhop");
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let totals: Vec<u64> = [1usize, 4]
        .iter()
        .map(|&tw| {
            let eng = engine(&dir);
            let mut app = NHopApp::new(source, 4, traceroute::eattr::LATENCY_MS);
            app.hist_hi = 2000.0;
            eng.run(
                &app,
                &RunOptions {
                    timesteps: Some((0..6).collect()),
                    temporal_workers: tw,
                    ..Default::default()
                },
            )
            .unwrap();
            let composite = app.results.composite.lock().unwrap();
            composite.as_ref().unwrap().total()
        })
        .collect();
    assert_eq!(totals[0], totals[1], "temporal parallelism changed merge result");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Quantized final SSSP distances keyed (subgraph, local vertex) — the
/// sequential pattern exercises the cross-timestep carry that the
/// prefetcher pipelines around.
fn sssp_fingerprint(eng: &GopherEngine, gen: &TraceRouteGenerator, opts: &RunOptions) -> Vec<(u64, u32, i64)> {
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let app = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    eng.run(&app, opts).unwrap();
    let distances = app.results.distances.lock().unwrap();
    let mut out: Vec<(u64, u32, i64)> = distances
        .iter()
        .flat_map(|(sgid, (_, d))| {
            d.iter().enumerate().map(move |(lv, &x)| {
                let q = if x.is_finite() { (x as f64 * 1e6).round() as i64 } else { -1 };
                (sgid.0, lv as u32, q)
            })
        })
        .collect();
    out.sort_unstable();
    out
}

/// Loading timestep t+1 while t computes must not change any result —
/// prefetching only moves work earlier in wall-clock, never reorders
/// message delivery or carried state.
#[test]
fn prefetching_does_not_change_sequential_results() {
    let (gen, dir) = deployed("prefetch");
    let base = RunOptions { timesteps: Some((0..6).collect()), ..Default::default() };
    let with = sssp_fingerprint(
        &engine(&dir),
        &gen,
        &RunOptions { prefetch: true, ..base.clone() },
    );
    let without = sssp_fingerprint(
        &engine(&dir),
        &gen,
        &RunOptions { prefetch: false, workers: 1, ..base.clone() },
    );
    assert!(!with.is_empty());
    assert_eq!(with, without, "prefetch/parallel load changed SSSP results");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Prefetching must also leave the independent-pattern merge/fingerprint
/// machinery untouched (it only engages for the sequential pattern).
#[test]
fn prefetch_flag_is_inert_for_independent_pattern() {
    let (gen, dir) = deployed("prefetch-ind");
    let base = RunOptions { timesteps: Some(vec![0, 1, 2]), ..Default::default() };
    let a = pagerank_fingerprint(&engine(&dir), &gen, &RunOptions { prefetch: true, ..base.clone() });
    let b = pagerank_fingerprint(&engine(&dir), &gen, &RunOptions { prefetch: false, ..base.clone() });
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn stats_accounting_consistent() {
    let (gen, dir) = deployed("stats");
    let eng = engine(&dir);
    let app = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    let stats = eng
        .run(&app, &RunOptions { timesteps: Some(vec![0, 1]), temporal_workers: 1, ..Default::default() })
        .unwrap();
    assert_eq!(stats.per_timestep.len(), 2);
    for ts in &stats.per_timestep {
        // Fixed-iteration PR: supersteps = iterations + 1.
        assert_eq!(ts.supersteps, app.iterations + 1);
        assert!(ts.wall_s > 0.0);
        // cache misses <= slices read (each miss is exactly one read)
        assert_eq!(ts.cache_misses, ts.slices_read);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
