//! Storage integrity plane (gofs::vfs + gofs::scrub): seeded disk-fault
//! injection through the VFS shim, corrupt-slice detection / quarantine /
//! typed abort, replica mirroring with read-repair, offline scrub over
//! every crash window, and the chaos acceptance run — a cluster run over
//! a bit-rotted collection that heals from its replica and stays
//! bit-identical to a failure-free in-process run.

use goffish::cluster::coordinator::{run_coordinator, CoordinatorConfig};
use goffish::cluster::fault::{FaultInjector, FaultPlan};
use goffish::cluster::worker::{build_app, run_host, HostConfig};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{
    compact_collection, deploy, deploy_template, err_is_corrupt, open_collection, scrub,
    CollectionAppender, CompactOptions, CorruptSlice, DeployConfig, DiskModel, IngestOptions,
    Projection, ScrubOptions, StoreOptions,
};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::graph::SubgraphId;
use goffish::metrics::journal::{self, Journal};
use goffish::metrics::Metrics;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

const N_HOSTS: usize = 2;
const BINS: usize = 3;
const PACK: usize = 4;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gofs-scrub-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tr_gen() -> TraceRouteGenerator {
    TraceRouteGenerator::new(TraceRouteParams::tiny())
}

fn opts(cache: usize) -> StoreOptions {
    StoreOptions {
        cache_slots: cache,
        disk: DiskModel::instant(),
        metrics: Arc::new(Metrics::new()),
        ..Default::default()
    }
}

fn sssp_params(gen: &TraceRouteGenerator) -> Vec<(String, String)> {
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    vec![("source".to_string(), source.to_string())]
}

/// Recursive copy — builds a stand-in replica from a deployed tree.
fn copy_tree(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for e in std::fs::read_dir(src).unwrap() {
        let e = e.unwrap();
        let to = dst.join(e.file_name());
        if e.file_type().unwrap().is_dir() {
            copy_tree(&e.path(), &to);
        } else {
            std::fs::copy(e.path(), &to).unwrap();
        }
    }
}

/// Every sealed attribute slice of one partition (the layout nests them
/// as `attr/{v|e}<attr>/b<bin>-g<group>.slice`), sorted for determinism.
fn attr_slices(root: &Path, part: usize) -> Vec<PathBuf> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        for e in std::fs::read_dir(dir).unwrap() {
            let p = e.unwrap().path();
            if p.is_dir() {
                walk(&p, out);
            } else if p.extension().is_some_and(|x| x == "slice") {
                out.push(p);
            }
        }
    }
    let mut out = Vec::new();
    walk(&root.join(format!("part-{part}")).join("attr"), &mut out);
    out.sort();
    out
}

/// Flip one byte in place — simulated at-rest bit rot. Offset 16 lands
/// inside the container body, past the magic/version prefix.
fn flip_byte(path: &Path, offset: usize) {
    let mut bytes = std::fs::read(path).unwrap();
    assert!(bytes.len() > offset, "{} too short to corrupt", path.display());
    bytes[offset] ^= 0x40;
    std::fs::write(path, &bytes).unwrap();
}

/// Every value of every instance must read back identically from the two
/// collections (the bit-identity half of each recovery assertion).
fn assert_stores_identical(da: &Path, db: &Path, n_ts: usize) {
    let sa = open_collection(da, &opts(64)).unwrap();
    let sb = open_collection(db, &opts(64)).unwrap();
    assert_eq!(sa.len(), sb.len());
    for (a, b) in sa.iter().zip(&sb) {
        assert_eq!(a.n_instances(), n_ts, "store A instance count");
        assert_eq!(b.n_instances(), n_ts, "store B instance count");
        let proj = Projection::all(a.vertex_schema(), a.edge_schema());
        for sg in a.subgraphs() {
            for t in 0..n_ts {
                let ia = a.read_instance(sg.id.local(), t, &proj).unwrap();
                let ib = b.read_instance(sg.id.local(), t, &proj).unwrap();
                assert_eq!(ia.window, ib.window, "window t{t}");
                for attr in 0..a.vertex_schema().len() {
                    for v in 0..sg.n_vertices() as u32 {
                        assert_eq!(
                            ia.vertex_values(attr, v),
                            ib.vertex_values(attr, v),
                            "vattr {attr} v{v} t{t}"
                        );
                    }
                }
                for attr in 0..a.edge_schema().len() {
                    for e in 0..sg.edges.len() {
                        assert_eq!(
                            ia.edge_values(attr, e),
                            ib.edge_values(attr, e),
                            "eattr {attr} e{e} t{t}"
                        );
                    }
                }
            }
        }
    }
}

/// Full-projection scan of one store; returns the first read error.
fn scan_store(dir: &Path, part: usize, so: &StoreOptions) -> Result<(), anyhow::Error> {
    let stores = open_collection(dir, so)?;
    let s = &stores[part];
    let proj = Projection::all(s.vertex_schema(), s.edge_schema());
    for sg in s.subgraphs() {
        for t in 0..s.n_instances() {
            s.read_instance(sg.id.local(), t, &proj)?;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Replica mirroring (ingest --replica-dir)
// ---------------------------------------------------------------------

/// Every sealed file the appender publishes — template, metadata, and
/// attribute slices — lands in the replica bit-exactly; the WAL (mutable
/// primary state) is never mirrored.
#[test]
fn ingest_replica_mirrors_every_sealed_file_bit_exactly() {
    let gen = tr_gen();
    let n = gen.n_instances();
    let d = tmpdir("mirror");
    let rep = tmpdir("mirror-replica");
    deploy_template(&gen, &DeployConfig::new(N_HOSTS, BINS, PACK), &d).unwrap();
    let o = IngestOptions { replica_dir: Some(rep.clone()), ..Default::default() };
    let mut app = CollectionAppender::open(&d, o).unwrap();
    for t in 0..n {
        assert_eq!(app.append(&gen.instance(t)).unwrap(), t);
    }
    app.finish().unwrap();

    let mut mirrored = 0usize;
    for part in 0..N_HOSTS {
        let pd = d.join(format!("part-{part}"));
        for name in ["template.slice", "meta.slice"] {
            let primary = pd.join(name);
            let replica = rep.join(format!("part-{part}")).join(name);
            assert_eq!(
                std::fs::read(&primary).unwrap(),
                std::fs::read(&replica).unwrap(),
                "replica diverges for {}",
                replica.display()
            );
            mirrored += 1;
        }
        for primary in attr_slices(&d, part) {
            let rel = primary.strip_prefix(&d).unwrap();
            let replica = rep.join(rel);
            assert_eq!(
                std::fs::read(&primary).unwrap(),
                std::fs::read(&replica).unwrap(),
                "replica diverges for {}",
                replica.display()
            );
            mirrored += 1;
        }
        assert!(
            !rep.join(format!("part-{part}")).join("wal.log").exists(),
            "WAL must stay primary-only"
        );
    }
    assert!(mirrored > 2 * N_HOSTS, "no attribute slices were mirrored");
    std::fs::remove_dir_all(&d).unwrap();
    std::fs::remove_dir_all(&rep).unwrap();
}

// ---------------------------------------------------------------------
// Read-repair and the typed no-replica failure
// ---------------------------------------------------------------------

/// Bit rot on a sealed slice with a replica armed: reads succeed, the
/// primary is restored bit-exactly, and the journal records the
/// corrupt_detect → read_repair pair.
#[test]
fn read_repair_restores_primary_bit_exactly_and_journals() {
    let gen = tr_gen();
    let d = tmpdir("repair");
    deploy(&gen, &DeployConfig::new(N_HOSTS, BINS, PACK), &d).unwrap();
    let rep = tmpdir("repair-replica");
    copy_tree(&d, &rep);

    let victim = attr_slices(&d, 0).into_iter().next().unwrap();
    let clean_bytes = std::fs::read(&victim).unwrap();
    flip_byte(&victim, 16);
    assert_ne!(std::fs::read(&victim).unwrap(), clean_bytes);

    let jpath = d.join("journal.jsonl");
    let metrics = Arc::new(Metrics::new());
    metrics.set_journal(Arc::new(Journal::open(&jpath, "test").unwrap()));
    let so = StoreOptions {
        metrics,
        replica_dir: Some(rep.clone()),
        ..opts(16)
    };
    scan_store(&d, 0, &so).expect("read-repair must make every read succeed");

    assert_eq!(
        std::fs::read(&victim).unwrap(),
        clean_bytes,
        "primary not restored bit-exactly from replica"
    );
    assert!(
        !d.join("part-0").join(".quarantine").exists(),
        "repaired slice must not be quarantined"
    );
    let events = journal::replay(&jpath).unwrap();
    assert!(
        events.iter().any(|e| e.contains("\"event\":\"corrupt_detect\"")),
        "no corrupt_detect event journaled: {events:?}"
    );
    assert!(
        events.iter().any(|e| e.contains("\"event\":\"read_repair\"")),
        "no read_repair event journaled: {events:?}"
    );
    std::fs::remove_dir_all(&d).unwrap();
    std::fs::remove_dir_all(&rep).unwrap();
}

/// The same rot with no replica: the read fails with the typed
/// [`CorruptSlice`] naming the exact {part, group}, the bad file is
/// quarantined (not served, not silently deleted), and scrub then
/// reports the damage as corrupt — non-clean — with the same coordinates.
#[test]
fn corrupt_slice_without_replica_is_typed_quarantined_and_flagged_by_scrub() {
    let gen = tr_gen();
    let d = tmpdir("typed");
    deploy(&gen, &DeployConfig::new(N_HOSTS, BINS, PACK), &d).unwrap();
    for f in attr_slices(&d, 0) {
        flip_byte(&f, 16);
    }

    let err = scan_store(&d, 0, &opts(16)).expect_err("corrupt reads must fail");
    assert!(err_is_corrupt(&err), "not classified corrupt: {err:#}");
    let cs = err
        .downcast_ref::<CorruptSlice>()
        .expect("CorruptSlice payload must survive the context chain");
    assert_eq!(cs.part, 0);
    assert!(cs.group.is_some(), "attribute slice must carry its group id");
    assert!(cs.path.starts_with("part-0/"), "path not root-relative: {}", cs.path);
    assert!(
        !d.join(&cs.path).exists(),
        "corrupt file left in place: {}",
        cs.path
    );
    let quarantine = d.join("part-0").join(".quarantine");
    assert!(quarantine.exists(), "no quarantine directory");

    let report = scrub(&d, &ScrubOptions::default()).unwrap();
    assert!(!report.clean(), "scrub must flag a damaged store");
    assert!(
        report
            .corrupt
            .iter()
            .any(|f| f.part == Some(0) && f.group == cs.group && f.detail == "missing"),
        "scrub did not name the quarantined slice: {}",
        report.to_json()
    );
    assert!(
        report.self_healing.iter().any(|f| f.detail.contains("quarantined")),
        "quarantined copy not reported: {}",
        report.to_json()
    );
    std::fs::remove_dir_all(&d).unwrap();
}

// ---------------------------------------------------------------------
// Crash-window × scrub matrix
// ---------------------------------------------------------------------

/// A torn trailing WAL frame is self-healing: scrub stays clean, names
/// the tail, and recovery (replay + re-append) is bit-identical to an
/// uninterrupted deployment.
#[test]
fn scrub_classifies_torn_wal_tail_as_self_healing_and_recovery_is_bit_identical() {
    let gen = tr_gen();
    let cfg = DeployConfig::new(N_HOSTS, BINS, 8); // pack 8: nothing seals
    let d = tmpdir("wal-tail");
    deploy_template(&gen, &cfg, &d).unwrap();
    let mut app = CollectionAppender::open(&d, IngestOptions::default()).unwrap();
    for t in 0..3 {
        app.append(&gen.instance(t)).unwrap();
    }
    drop(app);
    let wal = d.join("part-0").join("wal.log");
    let bytes = std::fs::read(&wal).unwrap();
    std::fs::write(&wal, &bytes[..bytes.len() - 7]).unwrap();

    let report = scrub(&d, &ScrubOptions::default()).unwrap();
    assert!(report.clean(), "torn tail is not data loss: {}", report.to_json());
    assert!(
        report.self_healing.iter().any(|f| f.detail.contains("torn WAL tail")),
        "torn tail not classified: {}",
        report.to_json()
    );

    // Recovery: replay truncates the torn record, re-append it and one
    // more, seal, and compare with a 4-instance batch deployment.
    let mut app = CollectionAppender::open(&d, IngestOptions::default()).unwrap();
    assert_eq!(app.n_instances(), 2, "torn record dropped on replay");
    for t in 2..4 {
        assert_eq!(app.append(&gen.instance(t)).unwrap(), t);
    }
    app.finish().unwrap();
    let gen4 = TraceRouteGenerator::new(TraceRouteParams {
        n_instances: 4,
        ..TraceRouteParams::tiny()
    });
    let d_batch = tmpdir("wal-tail-batch");
    deploy(&gen4, &cfg, &d_batch).unwrap();
    assert_stores_identical(&d_batch, &d, 4);
    assert!(scrub(&d, &ScrubOptions::default()).unwrap().clean());
    std::fs::remove_dir_all(&d).unwrap();
    std::fs::remove_dir_all(&d_batch).unwrap();
}

/// A crash mid-seal (meta publish fails after the group's attribute
/// slices hit disk) leaves only self-healing residue — the publish-last
/// ordering means the group table never references a half-written seal —
/// and the reopened appender replays the WAL and re-seals bit-identically.
#[test]
fn scrub_classifies_interrupted_seal_as_self_healing_and_recovery_is_bit_identical() {
    let gen = tr_gen();
    let n = gen.n_instances();
    let cfg = DeployConfig::new(N_HOSTS, BINS, PACK);
    let d = tmpdir("seal-crash");
    deploy_template(&gen, &cfg, &d).unwrap();

    let plan = FaultPlan::parse("on gofs.write.part-0/meta.slice nth 1 eio\n").unwrap();
    let o = IngestOptions {
        fault: Some(Arc::new(FaultInjector::new(plan))),
        ..Default::default()
    };
    let mut app = CollectionAppender::open(&d, o).unwrap();
    for t in 0..PACK - 1 {
        app.append(&gen.instance(t)).unwrap();
    }
    // The PACK-th append triggers the first seal; its part-0 meta
    // publish fails after the attribute slices were written.
    let err = app.append(&gen.instance(PACK - 1)).unwrap_err();
    assert!(format!("{err:#}").contains("injected"), "{err:#}");
    drop(app);

    let report = scrub(&d, &ScrubOptions::default()).unwrap();
    assert!(report.clean(), "interrupted seal is not data loss: {}", report.to_json());

    // Recovery: the WAL still holds every appended record; a fresh
    // appender replays them, re-seals, and streams the rest.
    let mut app = CollectionAppender::open(&d, IngestOptions::default()).unwrap();
    assert_eq!(app.n_instances(), PACK, "WAL must retain the unsealed records");
    for t in PACK..n {
        assert_eq!(app.append(&gen.instance(t)).unwrap(), t);
    }
    app.finish().unwrap();
    let d_batch = tmpdir("seal-crash-batch");
    deploy(&gen, &cfg, &d_batch).unwrap();
    assert_stores_identical(&d_batch, &d, n);
    assert!(scrub(&d, &ScrubOptions::default()).unwrap().clean());
    std::fs::remove_dir_all(&d).unwrap();
    std::fs::remove_dir_all(&d_batch).unwrap();
}

/// Every compaction crash window (ARCHITECTURE.md crash-window table):
/// scrub classifies the residue as self-healing — never corrupt — and a
/// re-run completes the pass bit-identically.
#[test]
fn scrub_classifies_compaction_crash_windows_and_rerun_is_bit_identical() {
    use goffish::gofs::ingest::compact::CrashPoint;
    let gen = tr_gen();
    let n = 8usize;
    let cfg = DeployConfig::new(N_HOSTS, BINS, 1);
    let gen8 = TraceRouteGenerator::new(TraceRouteParams {
        n_instances: n,
        ..TraceRouteParams::tiny()
    });
    let d_batch = tmpdir("cc-batch");
    deploy(&gen8, &cfg, &d_batch).unwrap();

    for (tag, crash) in [
        ("midrepack", CrashPoint::MidRepack),
        ("prepublish", CrashPoint::BeforePublish),
        ("precleanup", CrashPoint::BeforeCleanup),
    ] {
        let d = tmpdir(&format!("cc-{tag}"));
        deploy_template(&gen, &cfg, &d).unwrap();
        let mut app = CollectionAppender::open(&d, IngestOptions::default()).unwrap();
        for t in 0..n {
            app.append(&gen.instance(t)).unwrap();
        }
        drop(app);

        let crashing = CompactOptions { crash, ..CompactOptions::new(3) };
        let err = compact_collection(&d, &crashing).unwrap_err();
        assert!(format!("{err:#}").contains("simulated crash"), "{tag}: {err:#}");

        let report = scrub(&d, &ScrubOptions::default()).unwrap();
        assert!(
            report.corrupt.is_empty(),
            "{tag}: crash residue misclassified as corrupt: {}",
            report.to_json()
        );
        assert!(
            !report.self_healing.is_empty(),
            "{tag}: crash residue went unnoticed: {}",
            report.to_json()
        );

        compact_collection(&d, &CompactOptions::new(3)).unwrap();
        assert!(scrub(&d, &ScrubOptions::default()).unwrap().clean(), "{tag}");
        assert_stores_identical(&d_batch, &d, n);
        std::fs::remove_dir_all(&d).unwrap();
    }
    std::fs::remove_dir_all(&d_batch).unwrap();
}

// ---------------------------------------------------------------------
// Fault-plan determinism
// ---------------------------------------------------------------------

/// Strip the one non-deterministic journal field (`mono_us`).
fn canon(line: &str) -> String {
    let Some(i) = line.find("\"mono_us\":") else {
        return line.to_string();
    };
    let start = i + "\"mono_us\":".len();
    let digits = line[start..]
        .find(|c: char| !c.is_ascii_digit() && c != ' ')
        .unwrap_or(line.len() - start);
    let end = start + digits;
    if line[end..].starts_with(',') {
        format!("{}{}", &line[..i], &line[end + 1..])
    } else {
        format!("{}{}", &line[..i.saturating_sub(1)], &line[end..])
    }
}

/// Same plan + seed → bit-identical canonical journal: every fault
/// firing and every lifecycle event replays in the same order with the
/// same fields across independent runs.
#[test]
fn fault_plan_journal_is_canonically_identical_across_same_seed_runs() {
    let run = |tag: &str| -> Vec<String> {
        let gen = tr_gen();
        let d = tmpdir(tag);
        deploy_template(&gen, &DeployConfig::new(N_HOSTS, BINS, PACK), &d).unwrap();
        let plan = FaultPlan::parse(
            "seed 11\non gofs.write.part-0/attr/* prob 0.5 bitflip\n\
             on gofs.write.part-1/meta.slice nth 2 torn-write\n",
        )
        .unwrap();
        let inj = Arc::new(FaultInjector::new(plan));
        let metrics = Arc::new(Metrics::new());
        let jpath = d.join("journal.jsonl");
        metrics.set_journal(Arc::new(Journal::open(&jpath, "ingest").unwrap()));
        inj.set_metrics(metrics.clone());
        let o = IngestOptions { metrics, fault: Some(inj), ..Default::default() };
        let mut app = CollectionAppender::open(&d, o).unwrap();
        for t in 0..gen.n_instances() {
            // Silent-corruption actions never fail the append.
            app.append(&gen.instance(t)).unwrap();
        }
        app.finish().unwrap();
        let events: Vec<String> =
            journal::replay(&jpath).unwrap().iter().map(|l| canon(l)).collect();
        std::fs::remove_dir_all(&d).unwrap();
        events
    };
    let a = run("canon-a");
    let b = run("canon-b");
    assert!(!a.is_empty(), "journal must record the run");
    assert!(
        a.iter().any(|l| l.contains("fault_fire")),
        "plan never fired: {a:?}"
    );
    assert!(
        a.iter().all(|l| !l.contains("mono_us")),
        "canonicalization left mono_us behind"
    );
    assert_eq!(a, b, "same plan + seed must journal identically");
}

// ---------------------------------------------------------------------
// Cluster integration
// ---------------------------------------------------------------------

fn wait_port(pf: &Path) -> u16 {
    let t0 = Instant::now();
    loop {
        if let Ok(s) = std::fs::read_to_string(pf) {
            if let Ok(p) = s.trim().parse() {
                return p;
            }
        }
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "coordinator never wrote its port file"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// In-process ground truth over a clean collection — identical emission
/// path to the coordinator's assembled output (see tests/distributed.rs).
fn expected_output(dir: &Path, app_name: &str, params: &[(String, String)]) -> String {
    let metrics = Arc::new(Metrics::new());
    let o = StoreOptions { metrics: metrics.clone(), ..opts(16) };
    let stores = open_collection(dir, &o).unwrap();
    let per_host_sgids: Vec<Vec<SubgraphId>> = stores
        .iter()
        .map(|s| s.shared().subgraphs.iter().map(|sg| sg.id).collect())
        .collect();
    let total_vertices: usize = stores
        .iter()
        .map(|s| s.shared().subgraphs.iter().map(|g| g.n_vertices()).sum::<usize>())
        .sum();
    let n_t = stores[0].n_instances();
    let app = build_app(app_name, params, total_vertices, stores[0].as_ref()).unwrap();
    let eng = GopherEngine::new(stores, ClusterSpec::new(N_HOSTS), metrics);
    eng.run(app.as_app(), &RunOptions::default()).unwrap();
    let mut out = String::new();
    for t in 0..n_t {
        for sgids in &per_host_sgids {
            out.push_str(&app.emit_timestep(t, sgids));
        }
    }
    out
}

/// Coordinator + one worker thread per partition over localhost TCP,
/// with caller-controlled store options (replica arming). Returns every
/// outcome instead of unwrapping so failure-path tests can assert on it.
#[allow(clippy::type_complexity)]
fn run_cluster_outcomes(
    dir: &Path,
    params: Vec<(String, String)>,
    tag: &str,
    store_opts: StoreOptions,
) -> (Result<String, anyhow::Error>, Vec<Result<(), anyhow::Error>>) {
    let port_file = dir.join(format!("port-{tag}"));
    let cfg = CoordinatorConfig {
        n_hosts: N_HOSTS,
        listen: "127.0.0.1:0".to_string(),
        port_file: Some(port_file.clone()),
        app_name: "sssp".to_string(),
        app_params: params,
        ..Default::default()
    };
    let coord = std::thread::spawn(move || run_coordinator(&cfg));
    let addr = format!("127.0.0.1:{}", wait_port(&port_file));
    let hosts: Vec<_> = (0..N_HOSTS)
        .map(|part| {
            let cfg = HostConfig {
                root: dir.to_path_buf(),
                part,
                coordinator: addr.clone(),
                store_opts: store_opts.clone(),
                // Bound the exit paths: a fatal abort must not turn into
                // minutes of reconnect backoff against a dead listener.
                connect_timeout_s: 5,
                max_rejoins: 2,
                ..Default::default()
            };
            std::thread::spawn(move || run_host(&cfg))
        })
        .collect();
    let host_results = hosts.into_iter().map(|h| h.join().unwrap()).collect();
    (coord.join().unwrap(), host_results)
}

/// Unrepairable corruption on one partition, no replica: the worker
/// reports the typed reason and the coordinator fails the run with it —
/// promptly, instead of wedging through rejoin epochs over the same
/// bad bytes.
#[test]
fn cluster_run_over_corrupt_partition_fails_typed_instead_of_wedging() {
    let gen = tr_gen();
    let d = tmpdir("fatal");
    deploy(&gen, &DeployConfig::new(N_HOSTS, BINS, PACK), &d).unwrap();
    for f in attr_slices(&d, 1) {
        flip_byte(&f, 16);
    }

    let t0 = Instant::now();
    let (coord, hosts) =
        run_cluster_outcomes(&d, sssp_params(&gen), "fatal", opts(16));
    let err = coord.expect_err("coordinator must fail the run");
    assert!(
        format!("{err:#}").contains("corrupt slice (part 1"),
        "untyped coordinator failure: {err:#}"
    );
    assert!(
        hosts.iter().all(|h| h.is_err()),
        "every host must shut down after a fatal abort"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "fatal abort took {:?} — rejoin wedge?",
        t0.elapsed()
    );
    std::fs::remove_dir_all(&d).unwrap();
}

/// Chaos acceptance: ingest under a seeded storage fault plan (bit rot
/// on every part-0 attribute slice, a torn seal write on part-1) with a
/// replica armed, then a 2-host cluster run over the rotted primary.
/// Read-repair heals on demand and the output is bit-identical to a
/// failure-free in-process run; `scrub --repair` then restores the rest
/// and leaves the store value-identical to a clean deployment.
#[test]
fn chaos_cluster_run_heals_bit_rot_from_replica_bit_identically() {
    let gen = tr_gen();
    let n = gen.n_instances();
    let cfg = DeployConfig::new(N_HOSTS, BINS, PACK);
    let d_clean = tmpdir("chaos-clean");
    deploy(&gen, &cfg, &d_clean).unwrap();
    let d = tmpdir("chaos");
    deploy_template(&gen, &cfg, &d).unwrap();
    let rep = tmpdir("chaos-replica");

    let plan = FaultPlan::parse(
        "seed 5\non gofs.write.part-0/attr/* prob 1.0 bitflip\n\
         on gofs.write.part-1/attr/* nth 1 torn-write\n",
    )
    .unwrap();
    let o = IngestOptions {
        replica_dir: Some(rep.clone()),
        fault: Some(Arc::new(FaultInjector::new(plan))),
        ..Default::default()
    };
    let mut app = CollectionAppender::open(&d, o).unwrap();
    for t in 0..n {
        assert_eq!(app.append(&gen.instance(t)).unwrap(), t);
    }
    app.finish().unwrap();

    // The rot landed on the primary; the replica carried clean bytes.
    let rotted = attr_slices(&d, 0)
        .iter()
        .filter(|p| {
            let rel = p.strip_prefix(&d).unwrap();
            std::fs::read(p).unwrap() != std::fs::read(rep.join(rel)).unwrap()
        })
        .count();
    assert!(rotted > 0, "fault plan injected nothing");
    assert!(!scrub(&d, &ScrubOptions::default()).unwrap().clean());

    let params = sssp_params(&gen);
    let expected = expected_output(&d_clean, "sssp", &params);
    assert!(!expected.is_empty());
    let so = StoreOptions { replica_dir: Some(rep.clone()), ..opts(16) };
    let (coord, hosts) = run_cluster_outcomes(&d, params, "chaos", so);
    for (part, h) in hosts.into_iter().enumerate() {
        h.unwrap_or_else(|e| panic!("host {part} failed: {e:#}"));
    }
    let actual = coord.expect("chaos run must complete via read-repair");
    assert_eq!(actual, expected, "healed run diverged from failure-free run");

    // The run repaired what it read; scrub --repair restores the rest.
    let report = scrub(
        &d,
        &ScrubOptions { replica_dir: Some(rep.clone()), repair: true },
    )
    .unwrap();
    assert!(report.clean(), "repair left damage: {}", report.to_json());
    assert_stores_identical(&d_clean, &d, n);
    std::fs::remove_dir_all(&d_clean).unwrap();
    std::fs::remove_dir_all(&d).unwrap();
    std::fs::remove_dir_all(&rep).unwrap();
}
