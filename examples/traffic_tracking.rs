//! Traffic-camera vehicle tracking — the paper's Algorithm 1 end to end.
//!
//! A city grid of intersections with cameras records license plates per
//! 5-minute window; a fleet of vehicles drives persistent random walks.
//! We deploy the collection, then track one vehicle across windows with
//! the sequentially-dependent temporal traversal and compare against the
//! simulator's ground truth.
//!
//! ```sh
//! cargo run --release --example traffic_tracking
//! ```

use goffish::apps::VehicleTrackApp;
use goffish::cluster::ClusterSpec;
use goffish::datagen::{roadnet, CollectionSource, RoadNetGenerator, RoadNetParams};
use goffish::gofs::{deploy, open_collection, DeployConfig, StoreOptions};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let gen = RoadNetGenerator::new(RoadNetParams {
        width: 48,
        height: 48,
        n_vehicles: 300,
        n_instances: 16,
        ..Default::default()
    });
    println!(
        "road network: 48x48 grid, {} segments, {} vehicles, {} five-minute windows",
        gen.template().n_edges(),
        gen.params().n_vehicles,
        gen.n_instances()
    );

    let dir = std::env::temp_dir().join("goffish-traffic");
    let _ = std::fs::remove_dir_all(&dir);
    deploy(&gen, &DeployConfig::new(6, 10, 4), &dir)?;

    let metrics = Arc::new(Metrics::new());
    let opts = StoreOptions { metrics: metrics.clone(), ..Default::default() };
    let stores = open_collection(&dir, &opts)?;
    let engine = GopherEngine::new(stores, ClusterSpec::new(6), metrics);

    // Track vehicle 42 from its true starting intersection.
    let vehicle = 42;
    let plate = RoadNetGenerator::plate(vehicle);
    let start = gen.trajectory(0, vehicle)[0];
    let start_ext = gen.template().ext_ids[start as usize];
    println!("tracking plate {plate} from intersection v{start_ext}");

    let app = VehicleTrackApp::new(&plate, start_ext, roadnet::vattr::PLATES);
    let stats = engine.run(&app, &RunOptions::default())?;

    let traj = app.results.trajectory();
    println!(
        "tracked across {} timesteps ({} supersteps, {:.3}s): {} sightings",
        stats.per_timestep.len(),
        stats.total_supersteps(),
        stats.total_wall_s,
        traj.len()
    );
    let mut complete = true;
    for t in 0..gen.n_instances() {
        let seen: Vec<u64> = traj.iter().filter(|(ts, _)| *ts == t).map(|&(_, v)| v).collect();
        let truth: Vec<u64> = gen
            .trajectory(t, vehicle)
            .iter()
            .map(|&v| gen.template().ext_ids[v as usize])
            .collect();
        let hit = truth.iter().all(|v| seen.contains(v));
        complete &= hit;
        println!(
            "  window {t:2}: {} sightings, ground-truth path {} intersections, {}",
            seen.len(),
            truth.len(),
            if hit { "complete" } else { "MISSED" }
        );
    }
    std::fs::remove_dir_all(&dir)?;
    println!("traffic_tracking {}", if complete { "OK" } else { "INCOMPLETE" });
    Ok(())
}
