//! End-to-end driver (DESIGN.md "End-to-end validation"): a TR-scale-down
//! internet time-series graph through the **full stack** — synthetic
//! traceroute datagen → partitioner → GoFS deployment → 12-host Gopher
//! engine → all three paper applications (SSSP / N-hop / PageRank), with
//! the PageRank hot loop on the AOT-compiled JAX/Pallas kernels via PJRT
//! when artifacts are present. Prints the headline metrics recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example internet_analytics
//! # scale knobs: GOFFISH_VERTICES, GOFFISH_INSTANCES
//! ```

use goffish::apps::{NHopApp, PageRankApp, SsspApp};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{deploy, open_collection, DeployConfig, StoreOptions};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::{keys, Metrics};
use goffish::runtime::pjrt::{PjrtBackend, PjrtEngine};
use goffish::runtime::{LocalSpmv, ScalarBackend};
use goffish::util::bench::Table;
use std::sync::Arc;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n_vertices = env_usize("GOFFISH_VERTICES", 100_000);
    let n_instances = env_usize("GOFFISH_INSTANCES", 24);
    let n_hosts = 12; // the paper's testbed size

    println!("=== GoFFish-RS end-to-end internet analytics ===");
    let t0 = Instant::now();
    let gen = TraceRouteGenerator::new(TraceRouteParams {
        n_vertices,
        n_instances,
        traces_per_instance: 3_000,
        ..Default::default()
    });
    println!(
        "[datagen {:.1}s] TR-like: {} vertices, {} edges (ratio {:.2}), diameter≈{}, {} instances",
        t0.elapsed().as_secs_f64(),
        gen.template().n_vertices(),
        gen.template().n_edges(),
        gen.template().n_edges() as f64 / gen.template().n_vertices() as f64,
        gen.template().estimate_diameter(0),
        gen.n_instances()
    );

    let dir = std::env::temp_dir().join("goffish-internet");
    let _ = std::fs::remove_dir_all(&dir);
    let t1 = Instant::now();
    let report = deploy(&gen, &DeployConfig::new(n_hosts, 20, 20), &dir)?;
    println!(
        "[deploy {:.1}s] s20-i20 across {n_hosts} hosts: {} slices, {:.1} MB, subgraphs/partition {:?}",
        t1.elapsed().as_secs_f64(),
        report.slices_written,
        report.bytes_written as f64 / 1e6,
        report.subgraphs_per_partition
    );

    let metrics = Arc::new(Metrics::new());
    let opts = StoreOptions { cache_slots: 14, metrics: metrics.clone(), ..Default::default() };
    let stores = open_collection(&dir, &opts)?;
    let engine = GopherEngine::new(stores, ClusterSpec::new(n_hosts), metrics.clone());
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];

    let mut table = Table::new(&[
        "app", "pattern", "timesteps", "supersteps", "wall_s", "slices", "msgs", "sim_disk_s",
        "sim_net_s", "result",
    ]);

    // --- SSSP (sequentially dependent) over all instances. ---
    let sssp = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    let stats = engine.run(&sssp, &RunOptions::default())?;
    let last = stats.per_timestep.last().unwrap().timestep;
    let reached: usize = sssp
        .results
        .reached
        .lock()
        .unwrap()
        .iter()
        .filter(|((t, _), _)| *t == last)
        .map(|(_, &c)| c)
        .sum();
    push_row(&mut table, "sssp", "sequential", &stats, format!("{reached} reachable"));

    // --- N-hop latency (eventually dependent), N=6 as in the paper. ---
    let mut nhop = NHopApp::new(source, 6, traceroute::eattr::LATENCY_MS);
    nhop.hist_hi = 1500.0;
    let stats = engine.run(&nhop, &RunOptions::default())?;
    let arrivals = nhop.results.composite.lock().unwrap().as_ref().map(|h| h.total()).unwrap_or(0);
    push_row(&mut table, "nhop(6)", "eventually-dep", &stats, format!("{arrivals} arrivals"));

    // --- PageRank (independent) on the PJRT backend when available. ---
    let artifacts = std::path::PathBuf::from(
        std::env::var("GOFFISH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let (backend, backend_name): (Arc<dyn LocalSpmv>, &str) =
        match PjrtEngine::load(&artifacts, None, metrics.clone()) {
            Ok(eng) => (Arc::new(PjrtBackend::new(eng)), "pjrt"),
            Err(e) => {
                eprintln!("note: PJRT backend unavailable ({e}); falling back to scalar");
                (Arc::new(ScalarBackend), "scalar")
            }
        };
    let pr = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        backend,
    );
    let pr_ts: Vec<usize> = (0..n_instances.min(6)).collect();
    let stats = engine.run(&pr, &RunOptions { timesteps: Some(pr_ts), ..Default::default() })?;
    let top = pr.results.top_k(0, 1);
    push_row(
        &mut table,
        &format!("pagerank[{backend_name}]"),
        "independent",
        &stats,
        format!("top v{}", top.first().map(|t| t.0).unwrap_or(0)),
    );

    table.print("End-to-end results (TR synthetic, 12 simulated hosts)");
    println!(
        "kernel calls: {}, kernel time: {:.2}s",
        metrics.get(keys::KERNEL_CALLS),
        metrics.get(keys::KERNEL_NS) as f64 / 1e9
    );
    println!("total: {:.1}s", t0.elapsed().as_secs_f64());
    std::fs::remove_dir_all(&dir)?;
    Ok(())
}

fn push_row(
    table: &mut Table,
    app: &str,
    pattern: &str,
    stats: &goffish::gopher::RunStats,
    result: String,
) {
    let slices: u64 = stats.per_timestep.iter().map(|t| t.slices_read).sum();
    let msgs: u64 = stats.per_timestep.iter().map(|t| t.msgs_local + t.msgs_remote).sum();
    let disk: f64 = stats.per_timestep.iter().map(|t| t.sim_disk_ns).sum::<u64>() as f64 / 1e9;
    let net: f64 = stats.per_timestep.iter().map(|t| t.sim_net_ns).sum::<u64>() as f64 / 1e9;
    table.row(&[
        app.to_string(),
        pattern.to_string(),
        stats.per_timestep.len().to_string(),
        stats.total_supersteps().to_string(),
        format!("{:.2}", stats.total_wall_s),
        slices.to_string(),
        msgs.to_string(),
        format!("{disk:.2}"),
        format!("{net:.2}"),
        result,
    ]);
}
