//! Quickstart: generate a tiny time-series graph, deploy it into GoFS,
//! run one app per design pattern, and print results.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use goffish::apps::{NHopApp, PageRankApp, SsspApp};
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{deploy, open_collection, DeployConfig, StoreOptions};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use goffish::runtime::ScalarBackend;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // 1. A small synthetic traceroute collection: 2k routers, 12 windows.
    let gen = TraceRouteGenerator::new(TraceRouteParams {
        n_vertices: 2_000,
        n_instances: 12,
        traces_per_instance: 500,
        ..Default::default()
    });
    println!(
        "dataset: {} vertices, {} edges, {} instances",
        gen.template().n_vertices(),
        gen.template().n_edges(),
        gen.n_instances()
    );

    // 2. Deploy into GoFS: 4 hosts, 8 bins/partition, 4 instances/slice.
    let dir = std::env::temp_dir().join("goffish-quickstart");
    let _ = std::fs::remove_dir_all(&dir);
    let report = deploy(&gen, &DeployConfig::new(4, 8, 4), &dir)?;
    println!(
        "deployed: {} slices, {:.1} MB, subgraphs/partition {:?}",
        report.slices_written,
        report.bytes_written as f64 / 1e6,
        report.subgraphs_per_partition
    );

    // 3. Open the collection and start a 4-host Gopher engine.
    let metrics = Arc::new(Metrics::new());
    let opts = StoreOptions { metrics: metrics.clone(), ..Default::default() };
    let stores = open_collection(&dir, &opts)?;
    let engine = GopherEngine::new(stores, ClusterSpec::new(4), metrics);

    let source = gen.template().ext_ids[gen.vantages()[0] as usize];

    // 4a. Sequentially dependent: temporal SSSP.
    let sssp = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    let stats = engine.run(&sssp, &RunOptions::default())?;
    let reached = sssp.results.reached.lock().unwrap();
    let last = stats.per_timestep.last().unwrap().timestep;
    let n: usize = reached.iter().filter(|((t, _), _)| *t == last).map(|(_, &c)| c).sum();
    println!(
        "sssp (sequential): {} timesteps, {} supersteps, {n} vertices reachable",
        stats.per_timestep.len(),
        stats.total_supersteps()
    );

    // 4b. Independent: per-instance PageRank.
    let pr = PageRankApp::new(
        gen.template().n_vertices(),
        Some(traceroute::eattr::ACTIVE),
        Arc::new(ScalarBackend),
    );
    let stats =
        engine.run(&pr, &RunOptions { timesteps: Some(vec![0, 1, 2]), ..Default::default() })?;
    println!(
        "pagerank (independent): {} timesteps, top vertex at t=0: {:?}",
        stats.per_timestep.len(),
        pr.results.top_k(0, 1)
    );

    // 4c. Eventually dependent: 4-hop latency histogram with Merge.
    let mut nhop = NHopApp::new(source, 4, traceroute::eattr::LATENCY_MS);
    nhop.hist_hi = 1000.0;
    engine.run(&nhop, &RunOptions::default())?;
    let composite = nhop.results.composite.lock().unwrap();
    println!(
        "nhop (eventually dependent): composite histogram with {} arrivals",
        composite.as_ref().map(|h| h.total()).unwrap_or(0)
    );

    std::fs::remove_dir_all(&dir)?;
    println!("quickstart OK");
    Ok(())
}
