//! Traceroute feed: streaming ingestion interleaved with live analytics.
//!
//! Deploys a *template-only* (empty) collection, then runs two things at
//! once:
//!
//! * an **ingest thread** that appends one traceroute window at a time
//!   through the WAL-backed `CollectionAppender` — every `pack` windows
//!   seal into a published slice group, with a simulated crash (appender
//!   dropped mid-group and reopened from its WAL) along the way;
//! * a **follow-mode SSSP run** on the main thread that picks timesteps
//!   up as they land, prefetching ahead with the depth-k ring.
//!
//! ```sh
//! cargo run --release --example traceroute_feed
//! ```

use goffish::apps::SsspApp;
use goffish::cluster::ClusterSpec;
use goffish::datagen::{traceroute, CollectionSource, TraceRouteGenerator, TraceRouteParams};
use goffish::gofs::{
    deploy_template, open_collection, CollectionAppender, DeployConfig, IngestOptions,
    StoreOptions,
};
use goffish::gopher::{GopherEngine, RunOptions};
use goffish::metrics::Metrics;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // 1. A small synthetic traceroute feed: 2k routers, 12 windows.
    let gen = TraceRouteGenerator::new(TraceRouteParams {
        n_vertices: 2_000,
        n_instances: 12,
        traces_per_instance: 500,
        ..Default::default()
    });
    let n_windows = gen.n_instances();

    // 2. Deploy the skeleton only: 2 hosts, 8 bins, 4 windows per group.
    //    No instance data is written — the feed supplies it.
    let dir = std::env::temp_dir().join("goffish-traceroute-feed");
    let _ = std::fs::remove_dir_all(&dir);
    deploy_template(&gen, &DeployConfig::new(2, 8, 4), &dir)?;
    println!("deployed empty collection at {}", dir.display());

    // 3. Ingest thread: append window after window, sealing every 4.
    let feed_dir = dir.clone();
    let feed_gen = TraceRouteGenerator::new(TraceRouteParams {
        n_vertices: 2_000,
        n_instances: 12,
        traces_per_instance: 500,
        ..Default::default()
    });
    let feeder = std::thread::spawn(move || -> anyhow::Result<()> {
        let mut appender = CollectionAppender::open(&feed_dir, IngestOptions::default())?;
        for t in 0..n_windows {
            appender.append(&feed_gen.instance(t))?;
            println!(
                "[feed] t={t} appended ({} sealed / {} visible)",
                appender.sealed_instances(),
                appender.n_instances()
            );
            std::thread::sleep(Duration::from_millis(40));
            if t == 5 {
                // Simulated crash mid-group: drop the appender without
                // sealing and reopen — the WAL replay restores the open
                // tail and the feed continues as if nothing happened.
                drop(appender);
                appender = CollectionAppender::open(&feed_dir, IngestOptions::default())?;
                println!(
                    "[feed] crash + WAL replay at t={t}: {} instances recovered",
                    appender.n_instances()
                );
            }
        }
        let stats = appender.finish()?;
        println!(
            "[feed] done: {} appended, {} groups sealed, {:.1} MB WAL traffic",
            stats.appended,
            stats.sealed_groups,
            stats.wal_bytes as f64 / 1e6
        );
        Ok(())
    });

    // 4. Follow-mode SSSP over the growing collection.
    let metrics = Arc::new(Metrics::new());
    let opts = StoreOptions { metrics: metrics.clone(), ..Default::default() };
    let stores = open_collection(&dir, &opts)?;
    let engine = GopherEngine::new(stores, ClusterSpec::new(2), metrics);
    let source = gen.template().ext_ids[gen.vantages()[0] as usize];
    let sssp = SsspApp::new(source, traceroute::eattr::LATENCY_MS);
    let stats = engine.run(
        &sssp,
        &RunOptions {
            follow: true,
            follow_poll_ms: 20,
            follow_idle_polls: 100, // give the feed ~2s of slack
            prefetch_depth: 3,
            ..Default::default()
        },
    )?;

    feeder.join().expect("feed thread panicked")?;

    let slices: u64 = stats.per_timestep.iter().map(|t| t.slices_read).sum();
    println!(
        "follow-mode sssp: {} timesteps processed live, {} supersteps, {slices} slice reads",
        stats.per_timestep.len(),
        stats.total_supersteps()
    );
    assert_eq!(
        stats.per_timestep.len(),
        n_windows,
        "follow run should have processed every appended window"
    );

    std::fs::remove_dir_all(&dir)?;
    println!("traceroute feed OK");
    Ok(())
}
