"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the rust runtime.

HLO text — NOT serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts``. Writes one
``<name>_b{B}_k{K}.hlo.txt`` per kernel variant, a ``manifest.txt`` the
rust loader parses, and ``model.hlo.txt`` (the Makefile's freshness stamp
and smoke-test artifact).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import MODEL_FNS, shapes_for

# (family, B, K) variants to ship. B=128 matches the TPU MXU tile; smaller
# variants serve tests and small subgraphs.
VARIANTS = [
    ("pagerank", 32, 4),
    ("pagerank", 64, 8),
    ("pagerank", 128, 8),
    ("minplus", 32, 4),
    ("minplus", 64, 8),
    ("minplus", 128, 8),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name: str, b: int, k: int) -> str:
    fn = MODEL_FNS[name]
    args = shapes_for(name, b, k)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=None,
        help="comma-separated name:B:K triples overriding the default set",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    variants = VARIANTS
    if args.variants:
        variants = []
        for spec in args.variants.split(","):
            name, b, k = spec.split(":")
            variants.append((name, int(b), int(k)))

    manifest_lines = ["# kernel artifacts: <family> b=<B> k=<K> path=<file>"]
    for name, b, k in variants:
        fname = f"{name}_b{b}_k{k}.hlo.txt"
        text = lower_variant(name, b, k)
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} b={b} k={k} path={fname}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")

    # Composed-model smoke artifact + Makefile stamp (written last so an
    # interrupted build reruns).
    text = lower_variant("model", 32, 4)
    with open(os.path.join(args.out_dir, "model.hlo.txt"), "w") as f:
        f.write(text)
    print(f"wrote {os.path.join(args.out_dir, 'model.hlo.txt')} ({len(text)} chars)")


if __name__ == "__main__":
    main()
