"""Pure-jnp oracles for the Pallas kernels — the correctness signal.

pytest asserts the kernels (interpret mode) match these references across
shapes and data, and the rust integration tests assert the PJRT-loaded
artifacts match the rust scalar backends, closing the loop end-to-end.
"""

import jax.numpy as jnp


def pagerank_ref(a, x):
    """y[k,d] = sum_s a[k,s,d] * x[k,s]."""
    return jnp.einsum("ksd,ks->kd", a, x)


def minplus_ref(w, d):
    """o[k,j] = min_s (d[k,s] + w[k,s,j])."""
    return jnp.min(d[:, :, None] + w, axis=1)


def pagerank_iteration_ref(adj, ranks, out_deg, damping=0.85):
    """One dense synchronous PageRank iteration over a whole adjacency.

    adj: f32[N, N] (adj[s, d] = 1 for an edge s->d), ranks: f32[N],
    out_deg: f32[N]. Dangling mass is dropped (see apps/pagerank.rs note).
    """
    n = ranks.shape[0]
    contrib = jnp.where(out_deg > 0, ranks / jnp.maximum(out_deg, 1.0), 0.0)
    incoming = contrib @ adj
    return (1.0 - damping) / n + damping * incoming
