"""PageRank dense-tile SpMV kernel.

``y[k, d] = sum_s A[k, s, d] * x[k, s]`` — a batch of K independent
(1×B) @ (B×B) products. On a real TPU each grid step holds one B×B tile
(B=128 -> 64 KiB f32, MXU-shaped) plus two B-vectors in VMEM and drives
the systolic array with a single matmul; the HBM→VMEM schedule is the
grid over K expressed by the BlockSpecs, replacing the paper's per-vertex
scalar Java loop.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, o_ref):
    # Blocks arrive as (1, B) and (1, B, B); compute in f32 on the MXU.
    x = x_ref[0, :]
    a = a_ref[0, :, :]
    o_ref[0, :] = jnp.dot(x, a, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pagerank_tiles(a, x, *, interpret=True):
    """Batched tile SpMV.

    Args:
      a: f32[K, B, B] tile batch (rows = source, cols = destination).
      x: f32[K, B] source-block vectors.
      interpret: lower via the Pallas interpreter (required for CPU PJRT).

    Returns:
      f32[K, B]: per-tile destination contributions.
    """
    k, b, b2 = a.shape
    assert b == b2, f"tiles must be square, got {a.shape}"
    assert x.shape == (k, b), f"x shape {x.shape} != ({k}, {b})"
    return pl.pallas_call(
        _kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, b), jnp.float32),
        interpret=interpret,
    )(x, a)
