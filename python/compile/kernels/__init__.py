"""Layer-1 Pallas tile kernels (build-time only).

The GoFFish-RS hot spot — per-subgraph PageRank contribution sums and
min-plus SSSP relaxation — re-thought for a TPU MXU as batched dense-tile
operations (DESIGN.md §Hardware-Adaptation). Kernels are lowered with
``interpret=True`` so the emitted HLO runs on any PJRT backend (the
image's CPU plugin cannot execute Mosaic custom-calls); real-TPU
efficiency is estimated from the BlockSpec VMEM footprint in DESIGN.md.
"""

from .minplus import minplus_tiles
from .pagerank import pagerank_tiles

__all__ = ["pagerank_tiles", "minplus_tiles"]
