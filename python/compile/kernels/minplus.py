"""Min-plus (tropical) tile product kernel for SSSP relaxation.

``o[k, j] = min_s (d[k, s] + W[k, s, j])`` — one relaxation sweep over a
batch of K dense weight tiles. The tropical semiring has no MXU support,
so the inner op targets the VPU: a broadcasted add followed by a reduction
over the source axis, with the same VMEM tiling/BlockSpec schedule as the
PageRank kernel. Infinities are represented by a large finite sentinel
(see rust/src/runtime/pjrt.rs BIG) to keep min/plus well-defined in f32.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(d_ref, w_ref, o_ref):
    d = d_ref[0, :]          # (B,)
    w = w_ref[0, :, :]       # (B, B)
    o_ref[0, :] = jnp.min(d[:, None] + w, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def minplus_tiles(w, d, *, interpret=True):
    """Batched min-plus relaxation sweep.

    Args:
      w: f32[K, B, B] weight tiles (rows = source, cols = destination).
      d: f32[K, B] source-block distances.
      interpret: lower via the Pallas interpreter (required for CPU PJRT).

    Returns:
      f32[K, B]: candidate destination distances (caller folds with min).
    """
    k, b, b2 = w.shape
    assert b == b2, f"tiles must be square, got {w.shape}"
    assert d.shape == (k, b), f"d shape {d.shape} != ({k}, {b})"
    return pl.pallas_call(
        _kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, b), jnp.float32),
        interpret=interpret,
    )(d, w)
