"""Layer-2 JAX compute graphs, calling the L1 Pallas kernels.

Two artifact families (one per kernel) plus a composed whole-step
PageRank model used as the `model.hlo.txt` smoke artifact and by the
python tests. Everything here runs at build time only: `aot.py` lowers
these jitted functions to HLO text for the rust runtime.
"""

import jax
import jax.numpy as jnp

from .kernels import minplus_tiles, pagerank_tiles


def pagerank_tile_model(a, x):
    """The artifact function for `pagerank_b{B}_k{K}`: tuple-wrapped so the
    rust side can `to_tuple1()` uniformly."""
    return (pagerank_tiles(a, x),)


def minplus_tile_model(w, d):
    """The artifact function for `minplus_b{B}_k{K}`."""
    return (minplus_tiles(w, d),)


def pagerank_step_model(tiles, x_blocks, teleport, damping):
    """A composed L2 step: tile contributions + rank update, fused by XLA.

    tiles: f32[K, B, B]; x_blocks: f32[K, B] (contribution vectors per
    source block); returns damped, teleported destination blocks. Used as
    the `model.hlo.txt` stamp artifact and exercised by python tests; the
    rust hot path calls the leaner per-kernel artifacts and owns the
    scatter (sparsity structure) itself.
    """
    y = pagerank_tiles(tiles, x_blocks)
    return (teleport + damping * y,)


def shapes_for(name, b, k):
    """Example-argument shapes for lowering a kernel variant."""
    t = jax.ShapeDtypeStruct((k, b, b), jnp.float32)
    v = jax.ShapeDtypeStruct((k, b), jnp.float32)
    if name in ("pagerank", "minplus"):
        return (t, v)
    if name == "model":
        s = jax.ShapeDtypeStruct((), jnp.float32)
        return (t, v, s, s)
    raise ValueError(f"unknown artifact family {name}")


MODEL_FNS = {
    "pagerank": pagerank_tile_model,
    "minplus": minplus_tile_model,
    "model": pagerank_step_model,
}
