"""AOT path: lowering emits loadable HLO text with the expected shapes."""

import numpy as np

from compile.aot import lower_variant, to_hlo_text


def test_lowering_produces_hlo_text():
    text = lower_variant("pagerank", 8, 2)
    assert "HloModule" in text
    assert "f32[2,8,8]" in text
    assert "f32[2,8]" in text


def test_minplus_lowering_has_min_reduce():
    text = lower_variant("minplus", 8, 2)
    assert "HloModule" in text
    assert "minimum" in text


def test_model_artifact_lowering():
    text = lower_variant("model", 8, 2)
    assert "HloModule" in text


def test_hlo_text_entry_signature_matches_rust_loader_expectations():
    """The rust loader (`runtime/pjrt.rs`) expects two f32 parameters and a
    1-tuple root (return_tuple=True). Pin that contract in the text. The
    full execute-and-compare round trip is covered by the rust integration
    test `pjrt_kernels_match_scalar_backends`."""
    text = lower_variant("pagerank", 8, 2)
    header = text.splitlines()[0]
    assert "entry_computation_layout" in header, header
    sig = header.replace(" ", "")
    assert "f32[2,8,8]" in sig, sig
    assert "f32[2,8]" in sig, sig
    # Tuple-wrapped result: ...->(f32[2,8]{...})
    assert "->(f32[2,8]" in sig, sig


def test_variants_are_distinct_modules():
    t1 = lower_variant("pagerank", 8, 2)
    t2 = lower_variant("pagerank", 16, 2)
    assert "f32[2,8,8]" in t1 and "f32[2,16,16]" in t2
    assert np.all([t1 != t2])
