"""L2 correctness: composed models and whole-iteration semantics."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import pagerank_iteration_ref, pagerank_ref
from compile.model import pagerank_step_model, shapes_for, MODEL_FNS


def test_pagerank_step_model_composes():
    rng = np.random.default_rng(7)
    k, b = 4, 16
    tiles = rng.random((k, b, b), dtype=np.float32)
    x = rng.random((k, b), dtype=np.float32)
    teleport = np.float32(0.15 / 100.0)
    damping = np.float32(0.85)
    (got,) = pagerank_step_model(tiles, x, teleport, damping)
    want = teleport + damping * np.asarray(pagerank_ref(tiles, x))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 24), seed=st.integers(0, 2**31 - 1))
def test_dense_pagerank_iteration_conserves_nondangling_mass(n, seed):
    """Sanity of the whole-iteration reference the rust app is checked
    against: with no dangling vertices, total rank mass is conserved."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < 0.4).astype(np.float32)
    np.fill_diagonal(adj, 0)
    # Ensure no dangling: add a self-loopless fallback edge.
    for i in range(n):
        if adj[i].sum() == 0:
            adj[i, (i + 1) % n] = 1.0
    out_deg = adj.sum(axis=1)
    ranks = np.full(n, 1.0 / n, np.float32)
    for _ in range(5):
        ranks = np.asarray(pagerank_iteration_ref(adj, ranks, out_deg))
    np.testing.assert_allclose(ranks.sum(), 1.0, rtol=1e-4)


def test_shapes_for_covers_all_families():
    for name in MODEL_FNS:
        shapes = shapes_for(name, 8, 2)
        assert shapes[0].shape == (2, 8, 8)
