"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

Hypothesis sweeps shapes and data; fixed cases pin the artifact shapes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import minplus_tiles, pagerank_tiles
from compile.kernels.ref import minplus_ref, pagerank_ref

BIG = 1e30  # finite stand-in for +inf (matches rust/src/runtime/pjrt.rs)


def rand(shape, rng, lo=-2.0, hi=2.0):
    return (rng.random(shape, dtype=np.float32) * (hi - lo) + lo).astype(np.float32)


@pytest.mark.parametrize("k,b", [(1, 4), (4, 32), (8, 64), (2, 128)])
def test_pagerank_matches_ref_at_artifact_shapes(k, b):
    rng = np.random.default_rng(k * 1000 + b)
    a = rand((k, b, b), rng)
    x = rand((k, b), rng)
    got = np.asarray(pagerank_tiles(a, x))
    want = np.asarray(pagerank_ref(a, x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k,b", [(1, 4), (4, 32), (8, 64), (2, 128)])
def test_minplus_matches_ref_at_artifact_shapes(k, b):
    rng = np.random.default_rng(k * 2000 + b)
    w = rand((k, b, b), rng, 0.0, 10.0)
    d = rand((k, b), rng, 0.0, 50.0)
    got = np.asarray(minplus_tiles(w, d))
    want = np.asarray(minplus_ref(w, d))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 5),
    b=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pagerank_property(k, b, seed):
    rng = np.random.default_rng(seed)
    a = rand((k, b, b), rng, -5.0, 5.0)
    x = rand((k, b), rng, -5.0, 5.0)
    got = np.asarray(pagerank_tiles(a, x))
    want = np.asarray(pagerank_ref(a, x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 5),
    b=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    sparse=st.floats(0.0, 0.9),
)
def test_minplus_property_with_big_sentinels(k, b, seed, sparse):
    rng = np.random.default_rng(seed)
    w = rand((k, b, b), rng, 0.0, 100.0)
    # Knock out a fraction of cells to the BIG sentinel (absent edges).
    mask = rng.random((k, b, b)) < sparse
    w = np.where(mask, np.float32(BIG), w).astype(np.float32)
    d = rand((k, b), rng, 0.0, 100.0)
    got = np.asarray(minplus_tiles(w, d))
    want = np.asarray(minplus_ref(w, d))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_pagerank_zero_tiles_give_zero():
    a = np.zeros((2, 8, 8), np.float32)
    x = np.ones((2, 8), np.float32)
    got = np.asarray(pagerank_tiles(a, x))
    assert got.shape == (2, 8)
    np.testing.assert_array_equal(got, 0.0)


def test_minplus_identity_when_weights_big():
    w = np.full((1, 8, 8), BIG, np.float32)
    d = np.arange(8, dtype=np.float32)[None, :]
    got = np.asarray(minplus_tiles(w, d))
    # All candidates ~BIG: nothing below the sentinel scale.
    assert (got > 1e29).all()


def test_shape_mismatch_raises():
    a = np.zeros((2, 8, 8), np.float32)
    x = np.zeros((3, 8), np.float32)
    with pytest.raises(AssertionError):
        pagerank_tiles(a, x)
    w = np.zeros((2, 8, 4), np.float32)
    with pytest.raises(AssertionError):
        minplus_tiles(w, np.zeros((2, 8), np.float32))
